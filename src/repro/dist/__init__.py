"""Distributed substrate: ParamDef->mesh sharding and gradient collectives.

``repro.dist.sharding`` maps the axis tags declared on every ``ParamDef``
(``zero``/``tp``/``exp``/``layer``/``none``) onto the production
``("data", "model")`` / ``("pod", "data", "model")`` meshes, honoring a
MemoryPlan's placement (persist | hbm | host) via sharding memory kinds.

``repro.dist.collectives`` provides the wire-format-compressed gradient
synchronization primitives (bf16 cast, int8 + error feedback).
"""
from repro.compat import ensure_jax_compat

ensure_jax_compat()

from repro.dist import collectives, sharding  # noqa: E402,F401
