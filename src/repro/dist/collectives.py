"""Gradient-sync collectives with wire-format compression + error feedback.

Two compression levels for the gradient all-reduce:

  * ``bf16_all_reduce`` — cast to bf16 on the wire, mean across replicas;
  * ``compressed_all_reduce`` — int8 quantization (per-tensor absmax scale)
    with an error-feedback residual: each step transmits ``quantize(g + err)``
    and carries ``err' = (g + err) - dequantize(...)`` into the next step, so
    quantization error is fed back instead of lost (1-bit-Adam/PowerSGD-style
    EF; here at int8, the paper-adjacent "communication compression" knob the
    autotuner can trade against plan runtime via cost_model.GRAD_WIRE_FACTOR).

Single-controller note: under jit, XLA already inserts the reductions a
sharding implies. Passing ``mesh=None`` (what train/step_builder.py does for
the plan-gated path) applies the pure wire-format numerics to the
already-reduced gradients — exactly what a compressed collective would have
produced with synchronized replicas. Passing a mesh runs the actual
``shard_map`` collective, guarded on mesh size so 1-device meshes (and the
CPU test meshes) take the local math path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # moved to jax.shard_map in newer releases
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map  # type: ignore[attr-defined]


def _mesh_size(mesh) -> int:
    return math.prod(mesh.devices.shape)


def _replica_mean(x: jax.Array, mesh, axis_names) -> jax.Array:
    """Mean across all replicas of a replicated array via an explicit psum."""
    axes = tuple(axis_names) if axis_names is not None else tuple(mesh.axis_names)
    n = math.prod(dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in axes)

    def mean(v):
        return (jax.lax.psum(v.astype(jnp.float32), axes) / n).astype(x.dtype)

    return shard_map(mean, mesh=mesh, in_specs=P(), out_specs=P())(x)


# ---------------------------------------------------------------------------
# bf16 wire format
# ---------------------------------------------------------------------------
def bf16_all_reduce(x: jax.Array, mesh=None, axis_names=None) -> jax.Array:
    """Mean-all-reduce with bf16 on the wire; returns x's dtype."""
    xb = x.astype(jnp.bfloat16)
    if mesh is None or _mesh_size(mesh) == 1:
        return xb.astype(x.dtype)
    return _replica_mean(xb, mesh, axis_names).astype(x.dtype)


# ---------------------------------------------------------------------------
# int8 + error feedback
# ---------------------------------------------------------------------------
def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8: returns (q int8, scale fp32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_all_reduce(
    x: jax.Array, err: jax.Array, mesh=None, axis_names=None
) -> tuple[jax.Array, jax.Array]:
    """Int8 error-feedback mean-all-reduce.

    Returns ``(avg, new_err)`` with the invariant ``avg + new_err == x + err``
    on one device (nothing is lost — the residual carries exactly what the
    wire dropped) and ``|new_err|`` bounded by half a quantization step.
    """
    c = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize_int8(c)
    local = _dequantize_int8(q, scale)
    new_err = c - local
    if mesh is not None and _mesh_size(mesh) > 1:
        avg = _replica_mean(local, mesh, axis_names)
    else:
        avg = local
    return avg.astype(x.dtype), new_err.astype(err.dtype)


# ---------------------------------------------------------------------------
# Pytree variants (what the step builder consumes)
# ---------------------------------------------------------------------------
def init_error_feedback(grads):
    """fp32 zero residuals matching a gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def bf16_tree_all_reduce(grads, mesh=None, axis_names=None):
    return jax.tree.map(lambda g: bf16_all_reduce(g, mesh, axis_names), grads)


def compressed_tree_all_reduce(grads, errs, mesh=None, axis_names=None):
    """Leaf-wise compressed_all_reduce; returns (avg_tree, new_err_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    outs = [compressed_all_reduce(g, e, mesh, axis_names) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
