"""Gradient-sync collectives with wire-format compression + error feedback.

Two compression levels for the gradient all-reduce:

  * ``bf16_all_reduce`` — cast to bf16 on the wire, mean across replicas;
  * ``compressed_all_reduce`` — int8 quantization (per-tensor absmax scale)
    with an error-feedback residual: each step transmits ``quantize(g + err)``
    and carries ``err' = (g + err) - dequantize(...)`` into the next step, so
    quantization error is fed back instead of lost (1-bit-Adam/PowerSGD-style
    EF; here at int8, the paper-adjacent "communication compression" knob the
    autotuner trades against plan runtime via the calibrated wire factors in
    ``core/cost_model.py``; see docs/cost_model.md).

Two *sync paths* consume these numerics (``MemoryPlan.sync_mode``, dataflow
diagram in docs/architecture.md):

  * **xla** — under jit, GSPMD already inserts the reductions the shardings
    imply. Passing ``mesh=None`` (what train/step_builder.py does for this
    path) applies the pure wire-format numerics to the already-reduced
    gradients — exactly what a compressed collective would have produced with
    synchronized replicas, but the bytes XLA moves are the *uncompressed*
    gradients (calibration measures wire factor ~1.0: numerics only).
  * **manual** — the step builder runs loss/grad under ``shard_map`` and owns
    the reduction via the ``manual_*`` functions below: each device quantizes
    its local gradient (plus its error-feedback residual) to int8, the
    *compressed* payload is all-gathered over the sync axes (int8 on the
    wire — a gather-based all-reduce, the only reduction XLA lets us express
    with an integer wire dtype without overflow), and every device
    dequantizes and averages the shards locally. Real wire bytes drop by the
    quantization ratio; each device carries its own residual.

Everything outside a shard_map body is guarded on mesh size so 1-device
meshes (and the CPU test meshes) take the local math path; the manual
entry points are only ever called inside a shard_map body the step builder
guards the same way.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _mesh_size(mesh) -> int:
    return math.prod(mesh.devices.shape)


def _replica_mean(x: jax.Array, mesh, axis_names) -> jax.Array:
    """Mean across all replicas of a replicated array via an explicit psum."""
    axes = tuple(axis_names) if axis_names is not None else tuple(mesh.axis_names)
    n = math.prod(dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in axes)

    def mean(v):
        return (jax.lax.psum(v.astype(jnp.float32), axes) / n).astype(x.dtype)

    return shard_map(mean, mesh=mesh, in_specs=P(), out_specs=P())(x)


# ---------------------------------------------------------------------------
# bf16 wire format
# ---------------------------------------------------------------------------
def bf16_all_reduce(x: jax.Array, mesh=None, axis_names=None) -> jax.Array:
    """Mean-all-reduce with bf16 on the wire; returns x's dtype."""
    xb = x.astype(jnp.bfloat16)
    if mesh is None or _mesh_size(mesh) == 1:
        return xb.astype(x.dtype)
    return _replica_mean(xb, mesh, axis_names).astype(x.dtype)


# ---------------------------------------------------------------------------
# int8 + error feedback
# ---------------------------------------------------------------------------
def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8: returns (q int8, scale fp32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_all_reduce(
    x: jax.Array, err: jax.Array, mesh=None, axis_names=None
) -> tuple[jax.Array, jax.Array]:
    """Int8 error-feedback mean-all-reduce.

    Returns ``(avg, new_err)`` with the invariant ``avg + new_err == x + err``
    on one device (nothing is lost — the residual carries exactly what the
    wire dropped) and ``|new_err|`` bounded by half a quantization step.
    """
    c = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize_int8(c)
    local = _dequantize_int8(q, scale)
    new_err = c - local
    if mesh is not None and _mesh_size(mesh) > 1:
        avg = _replica_mean(local, mesh, axis_names)
    else:
        avg = local
    return avg.astype(x.dtype), new_err.astype(err.dtype)


# ---------------------------------------------------------------------------
# Manual sync primitives (called INSIDE a shard_map body; see step_builder)
# ---------------------------------------------------------------------------
def manual_mean(x: jax.Array, axis_names) -> jax.Array:
    """Uncompressed mean over the sync axes (fp32 accumulate on the wire)."""
    return jax.lax.pmean(x.astype(jnp.float32), axis_names).astype(x.dtype)


def manual_bf16_mean(x: jax.Array, axis_names) -> jax.Array:
    """Mean with bf16 on the wire: psum of the bf16-cast local value."""
    return jax.lax.pmean(x.astype(jnp.bfloat16), axis_names).astype(x.dtype)


def manual_int8_ef_sync(
    x: jax.Array, err: jax.Array, axis_names
) -> tuple[jax.Array, jax.Array]:
    """Int8+EF mean over the sync axes with the compressed payload on the wire.

    Gather-based all-reduce: quantize ``x + err`` locally, all-gather the int8
    payload and fp32 scales (int8 is what actually crosses the link — psum of
    int8 would overflow, so the sum happens after dequantization), then every
    device dequantizes and averages identically, keeping the result exactly
    replicated. ``err`` is per-device: each device feeds back what *its* wire
    transmission dropped.
    """
    c = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize_int8(c)
    new_err = c - _dequantize_int8(q, scale)
    qg = jax.lax.all_gather(q, axis_names)  # (n, *x.shape) int8 on the wire
    sg = jax.lax.all_gather(scale, axis_names)  # (n,) fp32 scales (negligible)
    deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * x.ndim)
    return jnp.mean(deq, axis=0).astype(x.dtype), new_err.astype(err.dtype)


def manual_tree_sync(grads, errs, axis_names, compress: str):
    """Leaf-wise manual gradient sync for one microbatch's local grad tree.

    Returns ``(synced_tree, new_err_tree)``; for the uncompressed modes the
    error tree passes through unchanged (residuals stay zero).
    """
    if compress == "int8_ef":
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(errs)
        outs = [manual_int8_ef_sync(g, e, axis_names) for g, e in zip(flat_g, flat_e)]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )
    sync = manual_bf16_mean if compress == "bf16" else manual_mean
    return jax.tree.map(lambda g: sync(g, axis_names), grads), errs


# ---------------------------------------------------------------------------
# Pytree variants (what the step builder consumes)
# ---------------------------------------------------------------------------
def init_error_feedback(grads):
    """fp32 zero residuals matching a gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def bf16_tree_all_reduce(grads, mesh=None, axis_names=None):
    return jax.tree.map(lambda g: bf16_all_reduce(g, mesh, axis_names), grads)


def compressed_tree_all_reduce(grads, errs, mesh=None, axis_names=None):
    """Leaf-wise compressed_all_reduce; returns (avg_tree, new_err_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    outs = [compressed_all_reduce(g, e, mesh, axis_names) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
