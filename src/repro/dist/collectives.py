"""Gradient-sync collectives with wire-format compression + error feedback.

Two compression levels for the gradient all-reduce:

  * ``bf16_all_reduce`` — cast to bf16 on the wire, mean across replicas;
  * ``compressed_all_reduce`` — int8 quantization (per-tensor absmax scale)
    with an error-feedback residual: each step transmits ``quantize(g + err)``
    and carries ``err' = (g + err) - dequantize(...)`` into the next step, so
    quantization error is fed back instead of lost (1-bit-Adam/PowerSGD-style
    EF; here at int8, the paper-adjacent "communication compression" knob the
    autotuner trades against plan runtime via the calibrated wire factors in
    ``core/cost_model.py``; see docs/cost_model.md).

Two *sync paths* consume these numerics (``MemoryPlan.sync_mode``, dataflow
diagram in docs/architecture.md):

  * **xla** — under jit, GSPMD already inserts the reductions the shardings
    imply. Passing ``mesh=None`` (what train/step_builder.py does for this
    path) applies the pure wire-format numerics to the already-reduced
    gradients — exactly what a compressed collective would have produced with
    synchronized replicas, but the bytes XLA moves are the *uncompressed*
    gradients (calibration measures wire factor ~1.0: numerics only).
  * **manual** — the step builder runs loss/grad under ``shard_map`` and owns
    the reduction via the ``manual_*`` functions below. Two topologies:

    - *replicated leaves* (DDP-style): each device quantizes its local
      gradient (plus its error-feedback residual) to int8, the *compressed*
      payload is all-gathered over the sync axes (int8 on the wire — a
      gather-based all-reduce, the only all-reduce XLA lets us express with
      an integer wire dtype without overflow), and every device dequantizes
      and averages the shards locally.
    - *ZeRO-sharded leaves* (``manual_*_reduce_scatter``): each device chunks
      its local full gradient along the sharded dim, quantizes per chunk, and
      an ``all_to_all`` delivers chunk *j*'s int8 payload (+ fp32 scale) to
      shard-owner *j*, which dequantizes and averages — a compressed
      reduce-scatter moving ``(z-1)/z`` of the int8 bytes per device, so each
      device ends up owning its ZeRO shard's reduced gradient. The EF
      residual is *shard*-sized: it feeds back the error of the chunk the
      device contributes to its own shard (the 1/z of the quantization error
      that re-enters this device's state; errors on chunks shipped to other
      owners are plain round-to-nearest noise, bounded by half a
      quantization step — see ``manual_int8_ef_reduce_scatter``).

    Real wire bytes drop by the quantization ratio; each device carries its
    own residual.

    ``gather_param_lazy`` completes the ZeRO-3 picture: a custom-vjp bf16
    param all-gather whose transpose runs the compressed reduce-scatter, so
    the manual zero3 path gathers each chunk just-in-time inside the layer
    scan and receives shard-sized gradients (and fresh EF residuals)
    straight out of AD — no up-front gather, no full-grad workspace.

Everything outside a shard_map body is guarded on mesh size so 1-device
meshes (and the CPU test meshes) take the local math path; the manual
entry points are only ever called inside a shard_map body the step builder
guards the same way.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import optimization_barrier, shard_map


def _mesh_size(mesh) -> int:
    return math.prod(mesh.devices.shape)


def _replica_mean(x: jax.Array, mesh, axis_names) -> jax.Array:
    """Mean across all replicas of a replicated array via an explicit psum."""
    axes = tuple(axis_names) if axis_names is not None else tuple(mesh.axis_names)
    n = math.prod(dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in axes)

    def mean(v):
        return (jax.lax.psum(v.astype(jnp.float32), axes) / n).astype(x.dtype)

    return shard_map(mean, mesh=mesh, in_specs=P(), out_specs=P())(x)


# ---------------------------------------------------------------------------
# bf16 wire format
# ---------------------------------------------------------------------------
def bf16_all_reduce(x: jax.Array, mesh=None, axis_names=None) -> jax.Array:
    """Mean-all-reduce with bf16 on the wire; returns x's dtype."""
    xb = x.astype(jnp.bfloat16)
    if mesh is None or _mesh_size(mesh) == 1:
        return xb.astype(x.dtype)
    return _replica_mean(xb, mesh, axis_names).astype(x.dtype)


# ---------------------------------------------------------------------------
# int8 + error feedback
# ---------------------------------------------------------------------------
def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8: returns (q int8, scale fp32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_all_reduce(
    x: jax.Array, err: jax.Array, mesh=None, axis_names=None
) -> tuple[jax.Array, jax.Array]:
    """Int8 error-feedback mean-all-reduce.

    Returns ``(avg, new_err)`` with the invariant ``avg + new_err == x + err``
    on one device (nothing is lost — the residual carries exactly what the
    wire dropped) and ``|new_err|`` bounded by half a quantization step.
    """
    c = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize_int8(c)
    local = _dequantize_int8(q, scale)
    new_err = c - local
    if mesh is not None and _mesh_size(mesh) > 1:
        avg = _replica_mean(local, mesh, axis_names)
    else:
        avg = local
    return avg.astype(x.dtype), new_err.astype(err.dtype)


# ---------------------------------------------------------------------------
# Manual sync primitives (called INSIDE a shard_map body; see step_builder)
# ---------------------------------------------------------------------------
def manual_mean(x: jax.Array, axis_names) -> jax.Array:
    """Uncompressed mean over the sync axes (fp32 accumulate on the wire)."""
    return jax.lax.pmean(x.astype(jnp.float32), axis_names).astype(x.dtype)


def manual_bf16_mean(x: jax.Array, axis_names) -> jax.Array:
    """Mean with bf16 on the wire: psum of the bf16-cast local value."""
    return jax.lax.pmean(x.astype(jnp.bfloat16), axis_names).astype(x.dtype)


def manual_int8_ef_sync(
    x: jax.Array, err: jax.Array, axis_names
) -> tuple[jax.Array, jax.Array]:
    """Int8+EF mean over the sync axes with the compressed payload on the wire.

    Gather-based all-reduce: quantize ``x + err`` locally, all-gather the int8
    payload and fp32 scales (int8 is what actually crosses the link — psum of
    int8 would overflow, so the sum happens after dequantization), then every
    device dequantizes and averages identically, keeping the result exactly
    replicated. ``err`` is per-device: each device feeds back what *its* wire
    transmission dropped.
    """
    c = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize_int8(c)
    new_err = c - _dequantize_int8(q, scale)
    qg = jax.lax.all_gather(q, axis_names)  # (n, *x.shape) int8 on the wire
    sg = jax.lax.all_gather(scale, axis_names)  # (n,) fp32 scales (negligible)
    deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * x.ndim)
    return jnp.mean(deq, axis=0).astype(x.dtype), new_err.astype(err.dtype)


def _names(axis_names) -> tuple[str, ...]:
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def _sync_extent(axis_names) -> int:
    """Extent of the (possibly compound) sync axis, inside a shard_map body.

    ``psum`` of a Python constant folds to the static axis size."""
    return int(jax.lax.psum(1, _names(axis_names)))


def _flat_axis_index(axis_names) -> jax.Array:
    """Row-major flattened device index over the sync axes — the shard-owner
    coordinate, matching both PartitionSpec layout and the device order
    jax.lax.all_to_all uses for a sequence of axis names."""
    idx = jnp.zeros((), jnp.int32)
    for a in _names(axis_names):
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def _pad_dim(x: jax.Array, dim: int, z: int) -> jax.Array:
    """Zero-pad ``dim`` up to the next multiple of z (uneven-divisor leaves).

    The state layout only ZeRO-shards evenly-divisible dims (dist/sharding
    keeps the rest replicated), so in the train step this is a no-op; the
    primitives still handle uneven dims so they compose as standalone
    collectives — every owner then holds the *padded* shard and the caller
    strips the tail."""
    pad = (-x.shape[dim]) % z
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[dim] = (0, pad)
    return jnp.pad(x, widths)


def _chunk(x: jax.Array, dim: int, z: int) -> jax.Array:
    """(…, dim, …) -> (z, …, dim/z, …): shard chunks moved to a leading axis."""
    x = _pad_dim(x, dim, z)
    shard = x.shape[dim] // z
    parts = x.reshape(x.shape[:dim] + (z, shard) + x.shape[dim + 1 :])
    return jnp.moveaxis(parts, dim, 0)


# Fused quantize/pack dispatch for the reduce-scatter wire path. Tri-state:
# None (default) auto-resolves to the Pallas kernel when the kernels package
# dispatches to Pallas; True/False force the path (differential tests drive
# both sides, and the fidelity/bench harnesses pin it for labeled rows).
_FUSED_QUANT: bool | None = None


def set_fused_quant(enabled: bool | None) -> None:
    """Force (True/False) or restore auto-resolution (None) of the fused
    int8 quantize+pack kernel in ``manual_int8_ef_reduce_scatter``."""
    global _FUSED_QUANT
    _FUSED_QUANT = enabled


def fused_quant_enabled() -> bool:
    if _FUSED_QUANT is not None:
        return _FUSED_QUANT
    from repro.kernels import pallas_kernels_active

    return pallas_kernels_active()


def manual_reduce_scatter(x: jax.Array, axis_names, dim: int,
                          wire_dtype=None) -> jax.Array:
    """Mean-reduce-scatter over the sync axes: returns this device's shard of
    the mean gradient, shard dim ``dim`` (padded to a multiple of the sync
    extent when uneven). ``wire_dtype`` casts the payload (bf16 wire format);
    default keeps fp32 accumulation."""
    z = _sync_extent(axis_names)
    xw = _pad_dim(x.astype(wire_dtype or jnp.float32), dim, z)
    out = jax.lax.psum_scatter(xw, _names(axis_names), scatter_dimension=dim,
                               tiled=True)
    return (out.astype(jnp.float32) / z).astype(x.dtype)


def manual_bf16_reduce_scatter(x: jax.Array, axis_names, dim: int) -> jax.Array:
    """Mean-reduce-scatter with bf16 on the wire."""
    return manual_reduce_scatter(x, axis_names, dim, wire_dtype=jnp.bfloat16)


def manual_int8_ef_reduce_scatter(
    x: jax.Array, err: jax.Array, axis_names, dim: int
) -> tuple[jax.Array, jax.Array]:
    """Int8+EF mean-reduce-scatter with the compressed payload on the wire.

    Each device splits its local full gradient into z shard-chunks along
    ``dim``, adds its shard-sized error-feedback residual to the chunk headed
    for *its own* shard, and quantizes each chunk with a per-chunk absmax
    scale. An ``all_to_all`` then ships chunk j's int8 payload (+ fp32 scale)
    to shard-owner j — int8 is what crosses the link; summing int8 would
    overflow, so the sum happens owner-side after dequantization. The owner
    dequantizes the z received chunks and averages: it now owns its ZeRO
    shard's reduced gradient.

    Returns ``(shard_mean, new_err)`` where both are shard-sized (``dim``
    divided by the sync extent, zero-padded when uneven). The residual
    carries exactly the error of this device's own-chunk transmission — the
    component that feeds back into the shard this device owns and updates;
    errors on the z-1 chunks shipped to other owners are not recoverable at
    shard-sized state and stay plain rounding noise (bounded by half a
    quantization step, i.e. |err| <= absmax/254 per element).
    """
    z = _sync_extent(axis_names)
    me = _flat_axis_index(axis_names)
    ch = _chunk(x.astype(jnp.float32), dim, z)  # (z, *shard_shape)
    ch = ch.at[me].add(err.astype(jnp.float32))
    if fused_quant_enabled():
        # One fused pass: absmax + quantize + pack + own-chunk EF residual
        # (kernels/fused_quant.py). Bit-identical to the three-op sequence
        # below when each path is jit'd separately; the unfused sequence
        # stays as the differential-testing / pallas-less fallback.
        from repro.kernels import fused_quantize_ef

        q, scale, new_err = fused_quantize_ef(ch, me)
    else:
        scale = jnp.maximum(
            jnp.max(jnp.abs(ch), axis=tuple(range(1, ch.ndim))), 1e-30) / 127.0
        q = jnp.clip(
            jnp.round(ch / scale.reshape((z,) + (1,) * (ch.ndim - 1))), -127, 127
        ).astype(jnp.int8)
        own_c = ch[me]
        new_err = own_c - q[me].astype(jnp.float32) * scale[me]
    qr = jax.lax.all_to_all(q, _names(axis_names), 0, 0)  # int8 on the wire
    sr = jax.lax.all_to_all(scale, _names(axis_names), 0, 0)  # (z,) fp32 scales
    deq = qr.astype(jnp.float32) * sr.reshape((z,) + (1,) * (qr.ndim - 1))
    return jnp.mean(deq, axis=0).astype(x.dtype), new_err.astype(err.dtype)


# ---------------------------------------------------------------------------
# Lazy per-chunk param gather (manual ZeRO-3; called INSIDE a shard_map body)
# ---------------------------------------------------------------------------
def _tiled_all_gather(x: jax.Array, axis_names, dim: int) -> jax.Array:
    return jax.lax.all_gather(x, _names(axis_names), axis=dim, tiled=True)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _gather_param_lazy(axis_names, dim, compress, w, err):
    return _tiled_all_gather(w, axis_names, dim)


def _gather_param_lazy_fwd(axis_names, dim, compress, w, err):
    return _tiled_all_gather(w, axis_names, dim), err


def _gather_param_lazy_bwd(axis_names, dim, compress, err, ct):
    if compress == "int8_ef":
        g_shard, new_err = manual_int8_ef_reduce_scatter(ct, err, axis_names, dim)
        return g_shard, new_err
    rs = manual_bf16_reduce_scatter if compress == "bf16" else manual_reduce_scatter
    return rs(ct, axis_names, dim), err


_gather_param_lazy.defvjp(_gather_param_lazy_fwd, _gather_param_lazy_bwd)


def gather_param_lazy(w: jax.Array, err, axis_names, dim: int,
                      compress: str = "int8_ef", anchor=None) -> jax.Array:
    """Just-in-time bf16 param all-gather whose transpose is the compressed
    reduce-scatter (the manual ZeRO-3 dataflow; see train/sync.py).

    Forward: tiled all-gather of this device's param shard along ``dim`` over
    the sync axes — the full leaf exists only at its point of use (inside the
    layer scan, so chunks are gathered one at a time; whether the gathered
    value survives to BWD or is re-gathered is the caller's remat policy —
    the plan's ``n_buffer``).

    Backward: the incoming cotangent is this device's *local full* gradient
    for the leaf; instead of materializing it into a workspace and syncing
    later, the VJP rule runs ``manual_int8_ef_reduce_scatter`` directly —
    each device receives only its owned grad shard straight out of AD, with
    the int8 payload on the wire.

    Error feedback threads through the VJP: ``err`` (shard-sized fp32, or
    None for bf16/none wire formats) is unused in the forward, and its
    "cotangent" is defined to be the *new* residual the reduce-scatter
    produces — so ``jax.grad`` w.r.t. ``(w, err)`` yields
    ``(grad_shard, new_err)`` and the caller carries the residual as explicit
    state keyed by chunk.

    ``anchor`` double-buffers the gather (the training twin of
    serve/paging's prefetch ordering): when given, the gathered leaf is
    ``optimization_barrier``-paired with the anchor value, so XLA may issue
    this chunk's all-gather as soon as the anchor exists — during the
    previous chunk's matmuls — but never earlier (pipeline depth stays
    bounded). The barrier is differentiable (compat.optimization_barrier
    barriers cotangents through a custom_vjp where needed), so the
    reduce-scatter transpose above is untouched.
    """
    g = _gather_param_lazy(tuple(_names(axis_names)), int(dim), compress, w, err)
    if anchor is not None:
        g, _ = optimization_barrier((g, anchor))
    return g


# Tree-level dispatch (replicated vs ZeRO-sharded leaves) lives in
# train/sync.py (manual_tree_sync): the strategy layer owns which primitive
# syncs which leaf; this module owns only the wire formats and topologies.


# ---------------------------------------------------------------------------
# Pytree variants (what the step builder consumes)
# ---------------------------------------------------------------------------
def init_error_feedback(grads):
    """fp32 zero residuals matching a gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def bf16_tree_all_reduce(grads, mesh=None, axis_names=None):
    return jax.tree.map(lambda g: bf16_all_reduce(g, mesh, axis_names), grads)


def compressed_tree_all_reduce(grads, errs, mesh=None, axis_names=None):
    """Leaf-wise compressed_all_reduce; returns (avg_tree, new_err_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    outs = [compressed_all_reduce(g, e, mesh, axis_names) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
