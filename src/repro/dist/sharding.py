"""ParamDef axis tags -> mesh shardings, honoring MemoryPlan placement.

Every ``ParamDef`` names its dims with tags (``layer``/``zero``/``tp``/
``exp``/``none``, see models/layers.py). This module is the single place
those tags meet a concrete ``jax.sharding.Mesh``:

  tag       persist            hbm / host               dp_only
  ------    ----------------   ----------------------   -----------------
  zero      replicated         sharded over zero axes   sharded over zero axes
  tp/exp    "model" axis       "model" axis             replicated
  layer     never sharded (the scan axis)
  none      never sharded

The *zero axes* are every mesh axis except ``model`` (``("data",)`` on the
single-pod mesh, ``("pod", "data")`` multi-pod). ``placement="host"``
additionally pins the sharding to the platform's host memory kind
(``pinned_host`` on TPU/GPU, ``unpinned_host`` on the CPU backend used by
tests; see repro/compat.py). ``dp_only=True`` repurposes the model axis as an
extra data axis: weights replicate across it and the batch shards over it.

A dim only takes an axis assignment when its size is divisible by the axis
extent — otherwise it stays replicated (tiny test models on forced
multi-device CPU meshes must lower cleanly, same policy as the KV-cache
shardings in train/step_builder.py).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import host_memory_kind
from repro.models.layers import EXP, LAYER, TP, ZERO, ParamDef

_is_def = lambda x: isinstance(x, ParamDef)  # noqa: E731


# ---------------------------------------------------------------------------
# Mesh geometry helpers
# ---------------------------------------------------------------------------
def mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def zero_axes(mesh) -> tuple[str, ...]:
    """ZeRO (data-parallel) axes: everything except the model axis."""
    return tuple(a for a in mesh.axis_names if a != "model")


def batch_axes(mesh, dp_only: bool = False) -> tuple[str, ...]:
    """Axes the batch dim shards over; with dp_only the model axis joins in."""
    return tuple(mesh.axis_names) if dp_only else zero_axes(mesh)


def _extent(mesh, axes: tuple[str, ...]) -> int:
    sizes = mesh_sizes(mesh)
    return math.prod(sizes[a] for a in axes)


def _entry(axes: tuple[str, ...]):
    """PartitionSpec entry: bare string for one axis, tuple for several."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _fits(mesh, dim: int, axes: tuple[str, ...]) -> bool:
    n = _extent(mesh, axes)
    return n == 1 or (dim % n == 0 and dim >= n)


# ---------------------------------------------------------------------------
# Single-def shardings
# ---------------------------------------------------------------------------
def _spec(d: ParamDef, mesh, placement: str, dp_only: bool) -> P:
    names = set(mesh.axis_names)
    used: set[str] = set()
    entries = []
    for dim, tag in zip(d.shape, d.axes):
        ax: tuple[str, ...] = ()
        if tag == ZERO and placement != "persist":
            ax = zero_axes(mesh)
        elif tag in (TP, EXP) and not dp_only and "model" in names:
            ax = ("model",)
        ax = tuple(a for a in ax if a not in used)
        if not ax or not _fits(mesh, dim, ax):
            entries.append(None)
            continue
        used.update(ax)
        entries.append(_entry(ax))
    return P(*entries)


def sharding_for(
    d: ParamDef, mesh, *, placement: str = "hbm", dp_only: bool = False
) -> NamedSharding:
    """Run-state sharding for one ParamDef under a chunk placement."""
    assert placement in ("persist", "hbm", "host"), placement
    spec = _spec(d, mesh, placement, dp_only)
    if placement == "host":
        kind = host_memory_kind(mesh)
        if kind is not None:
            return NamedSharding(mesh, spec, memory_kind=kind)
    return NamedSharding(mesh, spec)


def gather_sharding(d: ParamDef, mesh, *, dp_only: bool = False) -> NamedSharding:
    """Point-of-use layout: ZeRO axes gathered (replicated), TP kept, in
    device memory — the target of the per-chunk all-gather."""
    return NamedSharding(mesh, _spec(d, mesh, "persist", dp_only))


# ---------------------------------------------------------------------------
# Pytree variants
# ---------------------------------------------------------------------------
def tree_shardings(defs, mesh, *, placement: str = "hbm", dp_only: bool = False):
    return jax.tree.map(
        lambda d: sharding_for(d, mesh, placement=placement, dp_only=dp_only),
        defs, is_leaf=_is_def,
    )


def tree_specs(defs, shardings):
    """ShapeDtypeStruct pytree carrying the shardings (jit input specs)."""
    return jax.tree.map(
        lambda d, s: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype), sharding=s),
        defs, shardings, is_leaf=_is_def,
    )


def tree_gather_shardings(
    stacked_defs, mesh, *, persistent: bool = False, dp_only: bool = False
):
    """Per-repeat gather targets for a stacked block-def tree.

    The defs carry a leading ``layer`` axis (stacked superblock repeats); the
    gather happens inside the layer scan on one repeat's slice, so the specs
    drop that axis. Persistent runs return None: weights are already
    replicated and ``gather_weights`` skips the device_put entirely.
    """
    if persistent:
        return None

    def one(d: ParamDef) -> NamedSharding:
        if d.axes and d.axes[0] == LAYER:
            d = ParamDef(d.shape[1:], d.axes[1:], init=d.init, scale=d.scale, dtype=d.dtype)
        return gather_sharding(d, mesh, dp_only=dp_only)

    return jax.tree.map(one, stacked_defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Manual-sync shard_map specs (sync_mode="manual"; see train/step_builder.py)
# ---------------------------------------------------------------------------
def manual_sync_axes(mesh, dp_only: bool = False) -> tuple[str, ...]:
    """Mesh axes the manual gradient sync reduces over: the batch axes
    (== ZeRO axes; with dp_only the model axis joins them). The manual path
    requires params replicated over exactly these axes (all-persist plans)."""
    return batch_axes(mesh, dp_only)


def manual_batch_pspec(rank: int, mesh, dp_only: bool = False) -> P:
    """shard_map in_spec for a rank-``rank`` batch input: leading dim split
    over the sync axes, the rest replicated — the PartitionSpec twin of
    ``batch_sharding`` (which produces the jit-side NamedSharding)."""
    return P(_entry(manual_sync_axes(mesh, dp_only)), *([None] * (rank - 1)))


def leaf_sync_dim(sharding: NamedSharding, sync_axes: tuple[str, ...]) -> int | None:
    """Dim index a leaf ZeRO-shards over *exactly* the manual sync axes.

    Returns None for leaves the manual sync must treat as replicated — truly
    replicated leaves (persistent chunks, norms/scalars) and leaves whose
    tagged dim did not divide the axis extent (``_spec`` kept them whole).
    The full-axes-match requirement is what makes the reduce-scatter's
    shard-owner coordinate identical to the storage layout's."""
    target = _entry(tuple(sync_axes))
    for i, e in enumerate(sharding.spec):
        if e == target or (isinstance(e, (tuple, list)) and tuple(e) == tuple(sync_axes)):
            return i
    return None


def manual_state_pspecs(tree):
    """shard_map in/out specs for the train state under manual sync: each
    leaf's spec is its actual sharding (``P()`` for replicated leaves and
    unsharded scalars). All-persistent (DDP-kind) plans yield replicated
    specs everywhere; ZeRO-kind plans yield the sharded specs, so the body
    sees true local shards. Host memory kinds never appear here — manual
    eligibility (``MemoryPlan.manual_sync_kind``) excludes host chunks."""

    def ps(leaf):
        sh = getattr(leaf, "sharding", None)
        return sh.spec if isinstance(sh, NamedSharding) else P()

    return jax.tree.map(
        ps, tree,
        is_leaf=lambda x: isinstance(x, (jax.Array, jax.ShapeDtypeStruct)),
    )


# ---------------------------------------------------------------------------
# Batch / activation shardings
# ---------------------------------------------------------------------------
def batch_sharding(mesh, rank: int, dp_only: bool = False) -> NamedSharding:
    """Leading-dim batch sharding for a rank-``rank`` input array."""
    ba = batch_axes(mesh, dp_only)
    return NamedSharding(mesh, P(_entry(ba), *([None] * (rank - 1))))


def make_activation_sharder(mesh, plan) -> Callable[[jax.Array, str], jax.Array]:
    """Activation sharding constraints for the model's ``shard_act`` hook.

    Kinds (see models/model.py): ``bsd`` pins block-boundary activations
    (batch over the batch axes; the seq dim additionally over TP when the plan
    enables sequence parallelism), ``enter`` gathers a seq-sharded boundary
    back to batch-only before layer compute, ``logits`` shards the vocab dim
    over TP. Constraints are skipped for dims the mesh does not divide.
    """
    dp = bool(getattr(plan, "dp_only", False))
    ba = batch_axes(mesh, dp)
    tp = ("model",) if (not dp and "model" in mesh.axis_names) else ()
    if math.prod(mesh.devices.shape) == 1:
        return lambda x, kind="bsd": x
    seq_shard = bool(getattr(plan, "seq_shard_acts", False))

    def sharder(x: jax.Array, kind: str = "bsd") -> jax.Array:
        if x.ndim < 2:
            return x
        b = _entry(ba) if _fits(mesh, x.shape[0], ba) else None
        rest: list[Any] = [None] * (x.ndim - 1)
        if kind == "logits" and tp and _fits(mesh, x.shape[-1], tp):
            rest[-1] = _entry(tp)
        elif kind == "bsd" and seq_shard and tp and _fits(mesh, x.shape[1], tp):
            rest[0] = _entry(tp)
        # kind == "enter" (and non-SP "bsd"): batch-only, seq/feature replicated
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(b, *rest)))

    return sharder
