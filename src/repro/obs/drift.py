"""Online cost-model drift monitor: measured-vs-modeled, per step, in situ.

The offline fidelity check (benchmarks/estimator_fidelity.py) compares the
estimators against XLA's buffer assignment and a few timed steps once per
CI run. This monitor turns the same comparison into a *runtime* feedback
signal: construct it with the step's ``Workload`` and ``MemoryPlan`` (it
prices the plan once via ``estimate_runtime``/``estimate_memory``), feed it
each step's wall time and the device-memory watermark, and it maintains
rolling drift ratios the autotuner — or a future accelerator calibration
run — can consume without recompiling anything.

Ratio orientation matches the offline gate: ``predicted / measured``, so a
ratio above 1 means the model over-prices. ``band`` is the same symmetric
[1/T, T] acceptance band ``estimator_fidelity --fail-threshold`` enforces
(default 3.0). ``report()`` is the machine-readable payload written to
``drift_report.json`` by ``write()``; it carries the per-term modeled
decomposition (t_fwd/t_bwd/optimizer; states/activations/workspace) next to
the end-to-end ratios, so a drifting total can be attributed to the term
whose share the model got wrong.
"""
from __future__ import annotations

import json
import os
from collections import deque

from repro.obs.metrics import NULL_REGISTRY, MetricsRegistry, quantile

SCHEMA_VERSION = 1


class DriftMonitor:
    """Rolling measured-vs-modeled ratios for one (workload, plan) pair.

    ``window`` bounds the rolling step-time median (old steps age out, so a
    mid-run slowdown shows up instead of averaging away). ``registry``
    (optional) receives live ``drift.runtime_ratio`` / ``drift.memory_ratio``
    gauges on every observation.
    """

    def __init__(self, workload, plan, *, window: int = 50, band: float = 3.0,
                 registry: MetricsRegistry | None = None):
        from repro.core.cost_model import estimate_memory, estimate_runtime

        self.runtime = estimate_runtime(workload, plan)
        self.memory = estimate_memory(workload, plan)
        self.plan_desc = plan.describe()
        self.band = float(band)
        self.steps = 0
        self._times: deque[float] = deque(maxlen=window)
        self._mem_peak = 0
        self._mem_source = "none"
        self._reg = registry if registry is not None else NULL_REGISTRY

    # -- observations ---------------------------------------------------------
    def observe_step(self, wall_s: float,
                     device_mem_bytes: int | None = None,
                     mem_source: str = "reported") -> None:
        """One training step: wall time plus (optionally) the device-memory
        watermark measured around it (obs.mem.device_memory_watermark)."""
        self.steps += 1
        self._times.append(float(wall_s))
        if device_mem_bytes is not None and device_mem_bytes > self._mem_peak:
            self._mem_peak = int(device_mem_bytes)
            self._mem_source = mem_source
        self._reg.gauge("drift.runtime_ratio").set(self.runtime_ratio or 0.0)
        self._reg.gauge("drift.memory_ratio").set(self.memory_ratio or 0.0)

    # -- rolling ratios -------------------------------------------------------
    @property
    def measured_step_s(self) -> float | None:
        """Rolling median step time (the straggler-robust center)."""
        if not self._times:
            return None
        return quantile(self._times, 0.5)

    @property
    def runtime_ratio(self) -> float | None:
        m = self.measured_step_s
        if m is None or m <= 0:
            return None
        return self.runtime.t_iteration / m

    @property
    def memory_ratio(self) -> float | None:
        if self._mem_peak <= 0:
            return None
        return self.memory.peak / self._mem_peak

    def in_band(self, ratio: float | None) -> bool | None:
        if ratio is None:
            return None
        return 1.0 / self.band <= ratio <= self.band

    @property
    def ok(self) -> bool:
        """True when every *measured* ratio sits inside the band (an
        unmeasured dimension is not a failure — it is reported as null)."""
        verdicts = [self.in_band(self.runtime_ratio),
                    self.in_band(self.memory_ratio)]
        return all(v is not False for v in verdicts)

    # -- machine-readable report ---------------------------------------------
    def report(self) -> dict:
        rt_ratio = self.runtime_ratio
        mem_ratio = self.memory_ratio
        return {
            "schema": SCHEMA_VERSION,
            "kind": "drift_report",
            "plan": self.plan_desc,
            "band": self.band,
            "steps": self.steps,
            "ok": self.ok,
            "runtime": {
                "predicted_s": self.runtime.t_iteration,
                "measured_median_s": self.measured_step_s,
                "window": len(self._times),
                "ratio": rt_ratio,
                "in_band": self.in_band(rt_ratio),
                # modeled decomposition: where a drifting total should be
                # attributed (shares, not independently measured here)
                "terms": self.runtime.row(),
            },
            "memory": {
                "predicted_bytes": self.memory.peak,
                "measured_peak_bytes": self._mem_peak or None,
                "measured_source": self._mem_source,
                "ratio": mem_ratio,
                "in_band": self.in_band(mem_ratio),
                "terms": self.memory.row(),
            },
        }

    def write(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=2)
            f.write("\n")
        return path
