"""Structured logging: every human-readable line is also a JSONL record.

The training loop (and the example CLIs) used to log through a bare
``Callable[[str], None]`` — good for eyes, opaque to machines. A
``StructuredLogger`` keeps the human line byte-identical (it still goes to
the configured ``sink``, default ``print``) while emitting a parallel
machine-parseable record ``{"ts": ..., "level": ..., "logger": ...,
"event": ..., **fields}`` that is retained in memory and, when a
``jsonl_path`` is set, appended to disk as JSON Lines.

Legacy call sites that pass a plain callable keep working:
``as_logger(log)`` wraps it, so ``train_loop(log=print)`` and
``train_loop(log=my_list.append)`` behave exactly as before — the callable
becomes the human sink and the structured records ride alongside.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

LEVELS = ("debug", "info", "warning", "error")


class StructuredLogger:
    """``log(level, event, msg, **fields)`` -> human line + JSONL record.

    ``sink`` receives the human-readable line (default ``print``); set it to
    None to silence the human side (machine records still accumulate).
    ``min_level`` filters both sides. Records are plain dicts in ``records``
    (bounded by ``max_records``) and optionally appended to ``jsonl_path``.
    """

    def __init__(self, name: str, sink: Callable[[str], None] | None = print,
                 jsonl_path: str | None = None, min_level: str = "debug",
                 max_records: int = 1 << 16):
        self.name = name
        self.sink = sink
        self.records: list[dict] = []
        self.max_records = max_records
        self._min = LEVELS.index(min_level)
        self._file = None
        if jsonl_path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(jsonl_path)),
                        exist_ok=True)
            self._file = open(jsonl_path, "a")

    def log(self, level: str, event: str, msg: str | None = None,
            **fields) -> None:
        if LEVELS.index(level) < self._min:
            return
        rec = {"ts": time.time(), "level": level, "logger": self.name,
               "event": event, **fields}
        if msg is not None:
            rec["msg"] = msg
        if len(self.records) < self.max_records:
            self.records.append(rec)
        if self._file is not None:
            self._file.write(json.dumps(rec, default=str) + "\n")
            self._file.flush()
        if self.sink is not None and msg is not None:
            self.sink(msg)

    def debug(self, event: str, msg: str | None = None, **fields) -> None:
        self.log("debug", event, msg, **fields)

    def info(self, event: str, msg: str | None = None, **fields) -> None:
        self.log("info", event, msg, **fields)

    def warning(self, event: str, msg: str | None = None, **fields) -> None:
        self.log("warning", event, msg, **fields)

    def error(self, event: str, msg: str | None = None, **fields) -> None:
        self.log("error", event, msg, **fields)

    # legacy surface: a StructuredLogger is itself a Callable[[str], None],
    # so code that still does ``log(f"...")`` records an "info" event with
    # the line as its message
    def __call__(self, msg: str) -> None:
        self.info("log", msg)

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None


def as_logger(log, name: str = "loop") -> StructuredLogger:
    """Adapt the legacy ``log`` plumbing: a StructuredLogger passes through,
    any other callable becomes the human sink of a fresh one."""
    if isinstance(log, StructuredLogger):
        return log
    return StructuredLogger(name, sink=log)
