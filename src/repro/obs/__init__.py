"""Runtime telemetry subsystem shared by training and serving.

The paper's runtime profiler (§3.2) feeds its cost models with *measured*
latency, memory, and I/O — this package is that measurement layer for the
repo's runtime paths (the trace-time analogue lives in core/profiler.py):

  * ``metrics``  — labeled counters / gauges / histograms with a snapshot-
    to-dict registry (``MetricsRegistry``);
  * ``trace``    — nestable wall-clock spans exporting JSONL and Chrome-
    trace/Perfetto ``trace.json`` (``Tracer``);
  * ``logging``  — structured logger: every human line is also a JSONL
    record (``StructuredLogger``);
  * ``mem``      — device-memory watermark (backend ``memory_stats()`` with
    a live-array fallback);
  * ``drift``    — online measured-vs-modeled monitor emitting
    ``drift_report.json`` (``DriftMonitor``).

``Telemetry`` bundles one registry + tracer + logger. Instrumented code
resolves its handle through ``current_telemetry()`` — a module-level
default in the tri-state style of ``dist.collectives.set_fused_quant`` —
which returns the shared no-op ``NULL_TELEMETRY`` unless a caller installed
one (``set_default_telemetry`` / ``use_telemetry``). Telemetry is therefore
strictly opt-in: with none installed, instrumented paths execute no-op
handles and **never change the jitted programs** (the HLO-identity is
pinned by tests/test_obs.py).

API walkthrough and the metric-name table: docs/observability.md.
"""
from __future__ import annotations

import contextlib

from repro.obs.drift import DriftMonitor
from repro.obs.logging import StructuredLogger, as_logger
from repro.obs.mem import device_memory_watermark
from repro.obs.metrics import (
    DOCUMENTED_METRICS,
    NULL_REGISTRY,
    MetricsRegistry,
    quantile,
)
from repro.obs.trace import NULL_TRACER, Span, Tracer


class Telemetry:
    """One registry + tracer + logger, handed around as a unit.

    ``Telemetry()`` is fully on; ``Telemetry(trace=False)`` keeps the
    (cheap) registry while dropping span retention — what the decode engine
    uses as its default bookkeeping; ``NULL_TELEMETRY`` is all-off.
    """

    def __init__(self, *, metrics: bool = True, trace: bool = True,
                 logger: StructuredLogger | None = None, name: str = "repro"):
        self.registry: MetricsRegistry = (
            MetricsRegistry() if metrics else NULL_REGISTRY)
        self.tracer: Tracer = Tracer(enabled=trace)
        self.log: StructuredLogger = (
            logger if logger is not None else StructuredLogger(name))
        self.enabled = metrics or trace


class _NullTelemetry(Telemetry):
    def __init__(self):
        self.registry = NULL_REGISTRY
        self.tracer = NULL_TRACER
        self.log = StructuredLogger("null", sink=None, min_level="error",
                                    max_records=0)
        self.enabled = False


NULL_TELEMETRY = _NullTelemetry()

_default: Telemetry | None = None


def set_default_telemetry(tel: Telemetry | None) -> None:
    """Install (or clear, with None) the process-wide telemetry handle
    instrumented library code resolves via ``current_telemetry``."""
    global _default
    _default = tel


def current_telemetry() -> Telemetry:
    return _default if _default is not None else NULL_TELEMETRY


@contextlib.contextmanager
def use_telemetry(tel: Telemetry):
    """Scoped ``set_default_telemetry`` (restores the previous handle)."""
    global _default
    prev = _default
    _default = tel
    try:
        yield tel
    finally:
        _default = prev


__all__ = [
    "DOCUMENTED_METRICS",
    "DriftMonitor",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NULL_TELEMETRY",
    "NULL_TRACER",
    "Span",
    "StructuredLogger",
    "Telemetry",
    "Tracer",
    "as_logger",
    "current_telemetry",
    "device_memory_watermark",
    "quantile",
    "set_default_telemetry",
    "use_telemetry",
]
