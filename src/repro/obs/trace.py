"""Structured span tracer: nestable wall-clock spans, Chrome-trace export.

``tracer.span("gather_prefetch")`` is a context manager; spans nest through
a per-thread stack so concurrent engine/loop threads interleave without
locking the hot path (only the shared event list append is locked). Two
export forms:

  * ``write_jsonl(path)`` — one event per line, machine-grep-friendly;
  * ``write_chrome_trace(path)`` / ``to_chrome_trace()`` — the Chrome
    trace-event JSON (``{"traceEvents": [...]}``) Perfetto and
    ``chrome://tracing`` load directly: complete ("ph": "X") events with
    microsecond ``ts``/``dur``, instant ("ph": "i") marks, and process/
    thread-name metadata ("ph": "M").

Disabled tracers still *measure* (two ``perf_counter`` reads — the span
object's ``dur_s`` is always valid, which is what lets benchmark drivers use
one clock for their own reporting) but retain nothing, so the retained-event
path costs zero when telemetry is off.
"""
from __future__ import annotations

import json
import os
import threading
import time


class Span:
    """One timed region. ``dur_s`` is valid after the ``with`` block exits
    whether or not the tracer retains events."""

    __slots__ = ("name", "attrs", "t0_s", "dur_s", "depth", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0_s = 0.0
        self.dur_s = 0.0
        self.depth = 0

    def __enter__(self) -> "Span":
        self.depth = len(self._tracer._stack_of(threading.get_ident()))
        self._tracer._stack_of(threading.get_ident()).append(self)
        self.t0_s = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.dur_s = time.perf_counter() - self.t0_s
        stack = self._tracer._stack_of(threading.get_ident())
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self)


class Tracer:
    """Span recorder. ``enabled=False`` keeps the timing contract but drops
    every event (the no-op used when telemetry is off)."""

    def __init__(self, enabled: bool = True, max_events: int = 1 << 18):
        self.enabled = enabled
        self.max_events = max_events
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._stacks: dict[int, list] = {}
        self._epoch = time.perf_counter()

    def _stack_of(self, tid: int) -> list:
        got = self._stacks.get(tid)
        if got is None:
            got = self._stacks.setdefault(tid, [])
        return got

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration mark (Chrome "i" event)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i",
              "ts_s": time.perf_counter() - self._epoch, "dur_s": 0.0,
              "tid": threading.get_ident(), "depth": 0, "args": attrs}
        with self._lock:
            if len(self.events) < self.max_events:
                self.events.append(ev)

    def _record(self, span: Span) -> None:
        if not self.enabled:
            return
        ev = {"name": span.name, "ph": "X",
              "ts_s": span.t0_s - self._epoch, "dur_s": span.dur_s,
              "tid": threading.get_ident(), "depth": span.depth,
              "args": span.attrs}
        with self._lock:
            if len(self.events) < self.max_events:
                self.events.append(ev)

    # -- export ---------------------------------------------------------------
    def to_chrome_trace(self, process_name: str = "repro") -> dict:
        """Chrome trace-event format: ``ts``/``dur`` in microseconds,
        complete events per span, thread-name metadata per seen thread."""
        with self._lock:
            events = list(self.events)
        tids = sorted({e["tid"] for e in events})
        tid_ix = {t: i for i, t in enumerate(tids)}
        out = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                "args": {"name": process_name}}]
        for t in tids:
            out.append({"name": "thread_name", "ph": "M", "pid": 0,
                        "tid": tid_ix[t], "args": {"name": f"thread-{tid_ix[t]}"}})
        for e in events:
            rec = {"name": e["name"], "ph": e["ph"], "pid": 0,
                   "tid": tid_ix[e["tid"]],
                   "ts": round(e["ts_s"] * 1e6, 3)}
            if e["ph"] == "X":
                rec["dur"] = round(e["dur_s"] * 1e6, 3)
            if e["ph"] == "i":
                rec["s"] = "t"  # instant scope: thread
            if e["args"]:
                rec["args"] = {k: v for k, v in e["args"].items()}
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str, process_name: str = "repro") -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(process_name), f)
            f.write("\n")
        return path

    def write_jsonl(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with self._lock:
            events = list(self.events)
        with open(path, "w") as f:
            for e in events:
                f.write(json.dumps(e, default=str) + "\n")
        return path


NULL_TRACER = Tracer(enabled=False)
