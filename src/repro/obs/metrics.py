"""Zero-dependency metrics registry: counters, gauges, histograms.

The registry is the runtime bookkeeping layer ProTrain's profiler feeds
(§3.2's "precise estimates" need a measured counterpart): plain Python,
thread-safe, and cheap enough to leave on in serving hot loops — one dict
lookup plus a float add per operation, with the labeled-series handle
cacheable by the instrumented call site.

Series are identified by ``(name, sorted(labels))``. Three kinds:

  * ``Counter``   — monotone accumulator (``inc``), e.g. ticks, h2d bytes;
  * ``Gauge``     — last-write-wins level (``set``), e.g. pool occupancy,
    per-step wire-byte inventory, device-memory watermark (``set_max``);
  * ``Histogram`` — raw-sample series (``observe``) with nearest-rank
    quantiles, e.g. step wall time, inter-token latency.

``MetricsRegistry.snapshot()`` renders everything to one plain dict (JSON-
ready); ``NULL_REGISTRY`` is the shared no-op twin instrumented code uses
when telemetry is off, so call sites never branch.

Metric names used by the shipped instrumentation are enumerated in
``DOCUMENTED_METRICS`` and tabulated in docs/observability.md (a test keeps
the two in sync).
"""
from __future__ import annotations

import math
import threading


def quantile(values, q: float) -> float:
    """Nearest-rank quantile over an unsorted sequence.

    Edge cases are pinned (and unit-tested) because serving reports lean on
    them: an empty series returns 0.0 ("no data", NOT "zero latency" — the
    caller sees n == 0 in the same snapshot and must disambiguate there); a
    1-sample series returns that sample for every q in [0, 1]; q <= 0 is the
    minimum and q >= 1 the maximum.
    """
    xs = sorted(values)
    if not xs:
        return 0.0
    idx = min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))
    return xs[idx]


class Counter:
    """Monotone accumulator. ``inc`` with a negative amount raises."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name, self.labels = name, labels
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({amount})")
        self.value += amount


class Gauge:
    """Last-write-wins level; ``set_max`` keeps a high-watermark."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name, self.labels = name, labels
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def set_max(self, value: float) -> None:
        if value > self.value:
            self.value = float(value)


class Histogram:
    """Raw-sample series with nearest-rank quantiles.

    Samples are retained verbatim up to ``max_samples`` (default 1 << 16),
    then reservoir-free head truncation stops growth: the summary keeps
    count/sum exact and quantiles become the tail window's. Training and
    serving runs here are far below the cap; the cap only bounds memory on
    very long residencies.
    """

    __slots__ = ("name", "labels", "samples", "count", "total", "max_samples")

    def __init__(self, name: str, labels: tuple, max_samples: int = 1 << 16):
        self.name, self.labels = name, labels
        self.samples: list[float] = []
        self.count = 0
        self.total = 0.0
        self.max_samples = max_samples

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        self.samples.append(float(value))
        if len(self.samples) > self.max_samples:
            del self.samples[: len(self.samples) - self.max_samples]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def q(self, qq: float) -> float:
        return quantile(self.samples, qq)


def _series_key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


class MetricsRegistry:
    """Labeled-series store. Handle creation is locked; the handles
    themselves are single-writer by convention (one engine/loop thread), and
    float ops on them are GIL-atomic enough for the cross-thread readers the
    snapshot path serves."""

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[tuple, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, labels: dict):
        key = _series_key(name, labels)
        got = self._series.get(key)
        if got is None:
            with self._lock:
                got = self._series.setdefault(key, cls(name, key[1]))
        if not isinstance(got, cls):
            raise TypeError(f"metric {name}{labels} already registered as "
                            f"{type(got).__name__}, requested {cls.__name__}")
        return got

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def names(self) -> set[str]:
        with self._lock:
            return {name for name, _ in self._series}

    def snapshot(self) -> dict:
        """One JSON-ready dict: ``{name{label=v,...}: summary}``. Counters
        and gauges render their value; histograms a count/sum/quantile
        summary."""
        out: dict[str, dict] = {}
        with self._lock:
            series = list(self._series.values())
        for s in series:
            lbl = ",".join(f"{k}={v}" for k, v in s.labels)
            key = f"{s.name}{{{lbl}}}" if lbl else s.name
            if isinstance(s, Counter):
                out[key] = {"type": "counter", "value": s.value}
            elif isinstance(s, Gauge):
                out[key] = {"type": "gauge", "value": s.value}
            else:
                out[key] = {
                    "type": "histogram", "count": s.count, "sum": s.total,
                    "mean": s.mean,
                    "p50": s.q(0.50), "p99": s.q(0.99), "max": s.q(1.0),
                }
        return out


class _NullSeries:
    """Shared no-op handle: every mutator is a pass, so disabled-telemetry
    call sites pay one attribute lookup and a no-op call."""

    __slots__ = ()
    name = "null"
    labels = ()
    value = 0.0
    count = 0
    total = 0.0
    mean = 0.0
    samples: list[float] = []

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def set_max(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def q(self, qq: float) -> float:
        return 0.0


_NULL_SERIES = _NullSeries()


class NullRegistry(MetricsRegistry):
    """Disabled registry: hands out the shared no-op series and snapshots
    empty. Instrumented code never branches on enablement."""

    def __init__(self):
        super().__init__()

    def counter(self, name: str, **labels):
        return _NULL_SERIES

    gauge = counter
    histogram = counter

    def snapshot(self) -> dict:
        return {}


NULL_REGISTRY = NullRegistry()


# Metric names the shipped instrumentation emits; docs/observability.md
# tabulates each (tests/test_obs.py keeps table and tuple in sync), and
# benchmarks/telemetry_smoke.py asserts each is live after an instrumented
# train + serve run.
DOCUMENTED_METRICS = (
    # train/loop.py
    "train.step_time_s",
    "train.loss",
    "train.steps",
    "train.nan_skips",
    "train.straggler_events",
    "train.device_mem_watermark_bytes",
    # train/sync.py + step_builder
    "sync.wire_bytes_per_step",
    "sync.wire_payload",
    # serve/engine.py + serve/scheduler.py
    "serve.ticks",
    "serve.generated_tokens",
    "serve.admitted",
    "serve.evictions",
    "serve.rejected",
    "serve.truncated",
    "serve.finished",
    "serve.page_fetches",
    "serve.h2d_bytes",
    "serve.pagepool_free",
    "serve.pagepool_occupancy",
    "serve.itl_s",
    # obs/drift.py
    "drift.runtime_ratio",
    "drift.memory_ratio",
)
