"""Device-memory watermark: backend counters when available, live-array sum
as the fallback.

Real accelerator backends expose allocator statistics through
``Device.memory_stats()`` (``peak_bytes_in_use`` is the HBM watermark the
cost model's ``estimate_memory`` predicts). The forced-host CPU backend
returns nothing there, so the fallback sums the committed bytes of every
live ``jax.Array`` — that misses XLA's transient temp buffers (they live
only inside a step's execution) but tracks the resident model/optimizer/
cache state, which is the dominant term the drift monitor watches on CPU.
The returned ``source`` string says which measurement you got, so reports
never conflate the two.
"""
from __future__ import annotations

import jax

# memory_stats key preference: the peak watermark when the backend keeps
# one, else the current in-use level
_PEAK_KEYS = ("peak_bytes_in_use", "bytes_in_use", "bytes_in_use_current")


def device_memory_watermark() -> tuple[int, str]:
    """(bytes, source): source is "memory_stats" (allocator watermark) or
    "live_arrays" (sum of live committed jax.Array bytes)."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        stats = None
    if stats:
        for k in _PEAK_KEYS:
            v = stats.get(k)
            if v:
                return int(v), "memory_stats"
    total = 0
    for x in jax.live_arrays():
        try:
            total += x.nbytes
        except Exception:
            continue
    return int(total), "live_arrays"
