"""Mixed-precision Adam with fp32 master weights (paper §2: fp16/bf16 compute,
fp32 updates). Pure-pytree implementation (no optax dependency) so optimizer
state sharding/placement stays fully under the planner's control.

The Pallas ``fused_adam`` kernel (kernels/fused_adam.py) provides the fused
single-pass update for TPU; the jnp path here is the portable reference and
what the CPU tests run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    use_fused_kernel: bool = False


def init_opt_state(params) -> dict:
    """master: fp32 copy; m, v: fp32 zeros. Same tree structure as params."""
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": master,
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float, norm: jax.Array | None = None):
    """``norm`` overrides the locally-computed global norm — the manual ZeRO
    sync path holds shard-sized gradient leaves, so the true global norm
    needs a cross-device reduction the caller owns (train/sync.py)."""
    norm = global_norm(grads) if norm is None else norm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _update_leaf(p, g, master, m, v, *, cfg: AdamConfig, lr, bc1, bc2, fused: bool,
                 host: tuple | None = None):
    """One Adam leaf update. ``host`` = (param_shard, opt_host_shard,
    opt_dev_shard) for host-offloaded chunks: optimizer states round-trip
    device<->host (the TPU adaptation of the paper's CPU Adam — XLA schedules
    the DMA off the critical path; see DESIGN.md)."""
    if host is not None:
        p_shard, h_shard, d_shard = host
        master = jax.device_put(master, d_shard)
        m = jax.device_put(m, d_shard)
        v = jax.device_put(v, d_shard)
    if fused and host is None:
        # package-level dispatch: Pallas when the backend supports it (compat
        # .pallas_supported), pure-jnp reference otherwise — requesting the
        # fused kernel is always safe, never a crash on kernel-less backends
        from repro.kernels import fused_adam_update

        return fused_adam_update(
            p, g, master, m, v, lr=lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
            weight_decay=cfg.weight_decay, bc1=bc1, bc2=bc2,
        )
    gf = g.astype(jnp.float32)
    m_new = cfg.b1 * m + (1 - cfg.b1) * gf
    v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * master
    master_new = master - lr * upd
    p_new = master_new.astype(p.dtype)
    if host is not None:
        p_new = jax.device_put(p_new, p_shard)
        master_new = jax.device_put(master_new, h_shard)
        m_new = jax.device_put(m_new, h_shard)
        v_new = jax.device_put(v_new, h_shard)
    return p_new, master_new, m_new, v_new


def adam_update(params, grads, opt_state, cfg: AdamConfig, lr: float | jax.Array,
                host_plan: list | None = None, grad_norm: jax.Array | None = None):
    """Returns (new_params, new_opt_state, grad_norm).

    ``host_plan``: optional flat list aligned with the flattened params; each
    entry is None or (param_sharding, opt_host_sharding, opt_device_sharding)
    marking a host-offloaded leaf. ``grad_norm``: externally-computed global
    norm for clipping (manual ZeRO sync: leaves are device-local shards)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip, norm=grad_norm)
    count = opt_state["count"] + 1
    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_master = treedef.flatten_up_to(opt_state["master"])
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    if host_plan is None:
        host_plan = [None] * len(flat_p)

    outs = [
        _update_leaf(p, g, ma, m, v, cfg=cfg, lr=lr, bc1=bc1, bc2=bc2,
                     fused=cfg.use_fused_kernel, host=h)
        for p, g, ma, m, v, h in zip(flat_p, flat_g, flat_master, flat_m, flat_v, host_plan)
    ]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_state = {
        "master": treedef.unflatten([o[1] for o in outs]),
        "m": treedef.unflatten([o[2] for o in outs]),
        "v": treedef.unflatten([o[3] for o in outs]),
        "count": count,
    }
    return new_p, new_state, gnorm


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------
def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr
