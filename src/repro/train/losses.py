"""Loss functions.

Chunked cross-entropy never materializes the full (B, S, V) logits tensor: a
scan over sequence chunks keeps live logits at (B, ce_chunk, V). The custom
VJP recomputes logits per chunk in the backward pass (flash-CE) and — the
part XLA will not do for us — accumulates the head-weight gradient under an
explicit sharding constraint. Without it the transpose of the forward scan
carries a (V, D) fp32 accumulator partitioned only over whatever axis XLA
guessed, which at 128k-256k vocabularies is gigabytes per device.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def _chunk(x, n, c):
    return jnp.moveaxis(x.reshape(x.shape[0], n, c, *x.shape[2:]), 1, 0)


def _ce_forward(h, w, labels, c):
    b, s, d = h.shape
    n = s // c
    hc = _chunk(h, n, c)  # (n, B, c, D)
    lc = _chunk(labels, n, c)

    def body(total, inp):
        hh, ll = inp
        logits = (hh @ w).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return total + jnp.sum(lse - picked), lse

    total, lses = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s), lses  # lses: (n, B, c)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _ce(h, w, labels, c, w_acc_sharding):
    return _ce_forward(h, w, labels, c)[0]


def _ce_fwd(h, w, labels, c, w_acc_sharding):
    loss, lses = _ce_forward(h, w, labels, c)
    return loss, (h, w, labels, lses)


def _ce_bwd(c, w_acc_sharding, res, g):
    h, w, labels, lses = res
    b, s, d = h.shape
    n = s // c
    hc = _chunk(h, n, c)
    lc = _chunk(labels, n, c)
    scale = g / (b * s)

    dw0 = jnp.zeros(w.shape, jnp.float32)
    if w_acc_sharding is not None:
        dw0 = jax.lax.with_sharding_constraint(dw0, w_acc_sharding)

    def body(dw_acc, inp):
        hh, ll, lse = inp
        logits = (hh @ w).astype(jnp.float32)
        p = jnp.exp(logits - lse[..., None])  # softmax (B, c, V)
        onehot_sub = jnp.zeros_like(p).at[
            jnp.arange(p.shape[0])[:, None], jnp.arange(c)[None, :], ll
        ].set(1.0)
        dlogits = ((p - onehot_sub) * scale).astype(h.dtype)
        dh = dlogits @ w.T  # (B, c, D) bf16
        dw_new = dw_acc + jnp.einsum(
            "bcd,bcv->dv", hh, dlogits, preferred_element_type=jnp.float32
        )
        if w_acc_sharding is not None:
            dw_new = jax.lax.with_sharding_constraint(dw_new, w_acc_sharding)
        return dw_new, dh

    dw, dhs = jax.lax.scan(body, dw0, (hc, lc, lses))
    dh = jnp.moveaxis(dhs, 0, 1).reshape(h.shape)
    return dh, dw.astype(w.dtype), None


_ce.defvjp(_ce_fwd, _ce_bwd)


def chunked_cross_entropy(
    h: jax.Array,  # (B, S, D) final hidden states (already normed)
    head_w: jax.Array,  # (D, V)
    labels: jax.Array,  # (B, S) int32
    *,
    ce_chunk: int = 2048,
    w_acc_sharding: Any = None,
) -> jax.Array:
    b, s, d = h.shape
    c = min(ce_chunk, s)
    if s % c:
        c = s  # fall back to single chunk for odd lengths
    return _ce(h, head_w, labels, c, w_acc_sharding)
