"""Fault-tolerant training loop.

Responsibilities beyond calling step_fn in a loop:
  * auto-resume from the latest valid checkpoint (params+optimizer+data state);
  * periodic async checkpointing with atomic publish;
  * preemption handling (SIGTERM -> synchronous final save);
  * straggler/hang mitigation: a watchdog flags steps exceeding
    ``deadline_factor`` x the trailing-median step time (on real fleets this
    triggers re-slicing; here it logs and records, keeping the control path
    exercised and testable);
  * NaN-loss circuit breaker with skip-and-log (bad batch resilience).

Observability (repro.obs): every ``[loop]`` line goes through a
``StructuredLogger`` — the human-readable output is unchanged, and each line
is also a machine-parseable JSONL record. Passing ``telemetry=`` turns on
the runtime measurement layer: a ``train.step`` span per step, step-time
histogram, loss / device-memory-watermark gauges, straggler/nan counters,
and (with ``drift=``) the online measured-vs-modeled ``DriftMonitor``. All
instrumentation is host-side — the jitted step program is untouched whether
telemetry is on or off (HLO-identity pinned by tests/test_obs.py), and the
enabled-path overhead is bounded (<5% of a toy step, also pinned by test).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro import obs
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import PipelineState, SyntheticTokenPipeline
from repro.dist import collectives as COLL


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    deadline_factor: float = 3.0  # straggler threshold vs median step time
    max_nan_skips: int = 3


@dataclasses.dataclass
class LoopResult:
    steps_run: int
    final_step: int
    losses: list[float]
    resumed_from: int | None
    straggler_events: int
    nan_skips: int


def train_loop(
    step_artifacts,
    pipeline: SyntheticTokenPipeline,
    ckpt: CheckpointManager | None,
    loop_cfg: LoopConfig,
    *,
    init_key=None,
    log: Callable[[str], None] | obs.StructuredLogger = print,
    telemetry: obs.Telemetry | None = None,
    drift: obs.DriftMonitor | None = None,
) -> LoopResult:
    logger = obs.as_logger(log, name="loop")
    tel = telemetry if telemetry is not None else obs.NULL_TELEMETRY
    reg, tracer = tel.registry, tel.tracer
    step_time_h = reg.histogram("train.step_time_s")
    loss_g = reg.gauge("train.loss")
    mem_g = reg.gauge("train.device_mem_watermark_bytes")
    steps_c = reg.counter("train.steps")
    nan_c = reg.counter("train.nan_skips")
    straggler_c = reg.counter("train.straggler_events")

    jfn = jax.jit(step_artifacts.fn, donate_argnums=(0,))
    plan = getattr(step_artifacts, "plan", None)
    grad_compress = getattr(plan, "grad_compress", "none") if plan is not None else "none"
    if grad_compress != "none":
        suffix = " (error feedback in state)" if grad_compress == "int8_ef" else ""
        sync_mode = getattr(plan, "sync_mode", "xla")
        wire = "compressed payload on the wire" if sync_mode == "manual" else "wire numerics only"
        logger.info(
            "sync_config",
            f"[loop] gradient sync: {sync_mode} ({wire}), "
            f"compression: {grad_compress}{suffix}",
            sync_mode=sync_mode, grad_compress=grad_compress)

    # --- resume or init ------------------------------------------------------
    resumed_from = None
    start_step = 0
    state = None
    if ckpt is not None:
        specs = step_artifacts.state_specs
        try:
            got = ckpt.restore_latest(specs)
        except FileNotFoundError:
            if "ef" not in specs:
                raise
            # checkpoint predates grad compression: restore without the EF
            # residuals and cold-start them at their correct value, zero
            got = ckpt.restore_latest({k: v for k, v in specs.items() if k != "ef"})
            if got is not None:
                s0, st, extra = got
                st["ef"] = jax.tree.map(
                    lambda z, s: jax.device_put(z, s.sharding),
                    COLL.init_error_feedback(specs["ef"]), specs["ef"],
                )
                got = (s0, st, extra)
                logger.warning(
                    "ef_cold_start",
                    "[loop] checkpoint has no EF residuals; starting them at zero")
        if got is not None:
            start_step, state, extra = got
            pipeline.step = int(extra.get("data_step", start_step))
            resumed_from = start_step
            logger.info("resume",
                        f"[loop] resumed from checkpoint step {start_step}",
                        step=start_step)
    if state is None:
        key = init_key if init_key is not None else jax.random.PRNGKey(0)
        state = step_artifacts.init(key)

    # --- preemption handler ---------------------------------------------------
    preempted = {"flag": False}

    def on_term(sig, frame):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, on_term)

    losses: list[float] = []
    step_times: list[float] = []
    straggler_events = 0
    nan_skips = 0
    step = start_step
    try:
        while step < loop_cfg.total_steps:
            batch = pipeline.next_sync()
            t0 = time.perf_counter()
            with tracer.span("train.step", step=step):
                new_state, metrics = jfn(state, batch)
                loss = float(metrics["loss"])  # device sync: the step is done
            dt = time.perf_counter() - t0
            step_time_h.observe(dt)
            steps_c.inc()
            if tel.enabled:
                mem_bytes, mem_src = obs.device_memory_watermark()
                mem_g.set_max(mem_bytes)
            else:
                mem_bytes, mem_src = None, "none"
            if drift is not None:
                drift.observe_step(dt, mem_bytes, mem_source=mem_src)

            if not np.isfinite(loss):
                nan_skips += 1
                nan_c.inc()
                logger.warning(
                    "nan_skip",
                    f"[loop] step {step}: non-finite loss ({loss}); skipping batch",
                    step=step, loss=loss)
                if nan_skips > loop_cfg.max_nan_skips:
                    raise FloatingPointError("too many non-finite losses")
                # state was donated; fall back to last checkpoint or abort
                state = new_state  # donated buffers: keep going with updated state
                step += 1
                continue

            state = new_state
            losses.append(loss)
            loss_g.set(loss)
            step_times.append(dt)
            if len(step_times) >= 5:
                med = statistics.median(step_times[-50:])
                if dt > loop_cfg.deadline_factor * med:
                    straggler_events += 1
                    straggler_c.inc()
                    logger.warning(
                        "straggler",
                        f"[loop] step {step}: straggler ({dt:.3f}s vs median {med:.3f}s)",
                        step=step, dt_s=dt, median_s=med)

            if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                ef = metrics.get("ef_norm")
                ef_s = f" ef_norm={float(ef):.3g}" if ef is not None else ""
                fields: dict[str, Any] = {"step": step, "loss": loss,
                                          "dt_s": dt}
                if ef is not None:
                    fields["ef_norm"] = float(ef)
                logger.info(
                    "step",
                    f"[loop] step {step} loss={loss:.4f} ({dt*1e3:.0f} ms){ef_s}",
                    **fields)
            step += 1

            if ckpt is not None and step % loop_cfg.checkpoint_every == 0:
                with tracer.span("train.checkpoint", step=step):
                    ckpt.save(step, state, extra={"data_step": pipeline.step})
            if preempted["flag"]:
                logger.warning(
                    "preempt",
                    "[loop] preemption signal received: final checkpoint + exit",
                    step=step)
                if ckpt is not None:
                    ckpt.save(step, state, extra={"data_step": pipeline.step}, sync=True)
                break
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if ckpt is not None:
            if not preempted["flag"]:
                ckpt.save(step, state, extra={"data_step": pipeline.step}, sync=True)
            ckpt.wait()

    return LoopResult(
        steps_run=step - start_step,
        final_step=step,
        losses=losses,
        resumed_from=resumed_from,
        straggler_events=straggler_events,
        nan_skips=nan_skips,
    )
