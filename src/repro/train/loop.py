"""Fault-tolerant training loop.

Responsibilities beyond calling step_fn in a loop:
  * auto-resume from the latest valid checkpoint (params+optimizer+data state);
  * periodic async checkpointing with atomic publish;
  * preemption handling (SIGTERM -> synchronous final save);
  * straggler/hang mitigation: a watchdog flags steps exceeding
    ``deadline_factor`` x the trailing-median step time (on real fleets this
    triggers re-slicing; here it logs and records, keeping the control path
    exercised and testable);
  * NaN-loss circuit breaker with skip-and-log (bad batch resilience).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import PipelineState, SyntheticTokenPipeline
from repro.dist import collectives as COLL


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    deadline_factor: float = 3.0  # straggler threshold vs median step time
    max_nan_skips: int = 3


@dataclasses.dataclass
class LoopResult:
    steps_run: int
    final_step: int
    losses: list[float]
    resumed_from: int | None
    straggler_events: int
    nan_skips: int


def train_loop(
    step_artifacts,
    pipeline: SyntheticTokenPipeline,
    ckpt: CheckpointManager | None,
    loop_cfg: LoopConfig,
    *,
    init_key=None,
    log: Callable[[str], None] = print,
) -> LoopResult:
    jfn = jax.jit(step_artifacts.fn, donate_argnums=(0,))
    plan = getattr(step_artifacts, "plan", None)
    grad_compress = getattr(plan, "grad_compress", "none") if plan is not None else "none"
    if grad_compress != "none":
        suffix = " (error feedback in state)" if grad_compress == "int8_ef" else ""
        sync_mode = getattr(plan, "sync_mode", "xla")
        wire = "compressed payload on the wire" if sync_mode == "manual" else "wire numerics only"
        log(f"[loop] gradient sync: {sync_mode} ({wire}), "
            f"compression: {grad_compress}{suffix}")

    # --- resume or init ------------------------------------------------------
    resumed_from = None
    start_step = 0
    state = None
    if ckpt is not None:
        specs = step_artifacts.state_specs
        try:
            got = ckpt.restore_latest(specs)
        except FileNotFoundError:
            if "ef" not in specs:
                raise
            # checkpoint predates grad compression: restore without the EF
            # residuals and cold-start them at their correct value, zero
            got = ckpt.restore_latest({k: v for k, v in specs.items() if k != "ef"})
            if got is not None:
                s0, st, extra = got
                st["ef"] = jax.tree.map(
                    lambda z, s: jax.device_put(z, s.sharding),
                    COLL.init_error_feedback(specs["ef"]), specs["ef"],
                )
                got = (s0, st, extra)
                log("[loop] checkpoint has no EF residuals; starting them at zero")
        if got is not None:
            start_step, state, extra = got
            pipeline.step = int(extra.get("data_step", start_step))
            resumed_from = start_step
            log(f"[loop] resumed from checkpoint step {start_step}")
    if state is None:
        key = init_key if init_key is not None else jax.random.PRNGKey(0)
        state = step_artifacts.init(key)

    # --- preemption handler ---------------------------------------------------
    preempted = {"flag": False}

    def on_term(sig, frame):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, on_term)

    losses: list[float] = []
    step_times: list[float] = []
    straggler_events = 0
    nan_skips = 0
    step = start_step
    try:
        while step < loop_cfg.total_steps:
            batch = pipeline.next_sync()
            t0 = time.time()
            new_state, metrics = jfn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            if not np.isfinite(loss):
                nan_skips += 1
                log(f"[loop] step {step}: non-finite loss ({loss}); skipping batch")
                if nan_skips > loop_cfg.max_nan_skips:
                    raise FloatingPointError("too many non-finite losses")
                # state was donated; fall back to last checkpoint or abort
                state = new_state  # donated buffers: keep going with updated state
                step += 1
                continue

            state = new_state
            losses.append(loss)
            step_times.append(dt)
            if len(step_times) >= 5:
                med = statistics.median(step_times[-50:])
                if dt > loop_cfg.deadline_factor * med:
                    straggler_events += 1
                    log(f"[loop] step {step}: straggler ({dt:.3f}s vs median {med:.3f}s)")

            if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                ef = metrics.get("ef_norm")
                ef_s = f" ef_norm={float(ef):.3g}" if ef is not None else ""
                log(f"[loop] step {step} loss={loss:.4f} ({dt*1e3:.0f} ms){ef_s}")
            step += 1

            if ckpt is not None and step % loop_cfg.checkpoint_every == 0:
                ckpt.save(step, state, extra={"data_step": pipeline.step})
            if preempted["flag"]:
                log("[loop] preemption signal received: final checkpoint + exit")
                if ckpt is not None:
                    ckpt.save(step, state, extra={"data_step": pipeline.step}, sync=True)
                break
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if ckpt is not None:
            if not preempted["flag"]:
                ckpt.save(step, state, extra={"data_step": pipeline.step}, sync=True)
            ckpt.wait()

    return LoopResult(
        steps_run=step - start_step,
        final_step=step,
        losses=losses,
        resumed_from=resumed_from,
        straggler_events=straggler_events,
        nan_skips=nan_skips,
    )
