"""Gradient-sync strategy layer: who owns the reduce, and how it lowers.

``train/step_builder.py`` used to inline all sync control flow in its two
step bodies; this module owns it instead. A strategy object encapsulates one
``(sync_mode, layout-kind)`` pipeline:

  * ``XlaSync`` — ``sync_mode="xla"`` (and the 1-device manual fallback):
    GSPMD inserts the reduce implied by the shardings; ``finalize_grads``
    applies the compressed collective's wire *numerics* (int8+EF / bf16) to
    the already-reduced gradients. Wire bytes unchanged (calibrated factor
    ~1.0).
  * ``ManualSync`` — ``sync_mode="manual"`` on a multi-device mesh: the whole
    step body runs under ``shard_map`` and the only collectives in the
    program are the ones ``dist/collectives.py`` emits, so compressed
    payloads really cross the wire. One strategy covers both eligibility
    kinds (``MemoryPlan.manual_sync_kind``) through per-leaf descriptors:

      - a *replicated* leaf (all leaves of "ddp" plans; persistent chunks,
        norms, and non-divisible dims of "zero" plans) syncs DDP-style —
        quantize the full local grad, all-gather the int8 payload, dequantize
        and average identically everywhere; EF is per-device and stored
        stacked ``(n_sync, *shape)``, sharded over the sync axes;
      - a *ZeRO-sharded* leaf (``dist/sharding.leaf_sync_dim`` finds the dim
        carrying exactly the sync axes) reduce-scatters: chunk the local full
        grad along that dim, quantize per chunk, ``all_to_all`` the int8
        payload to shard owners, who dequantize and average — each device
        ends up owning its shard's reduced gradient and updates shard-local
        fp32 optimizer state in place. EF is *shard*-sized, laid out exactly
        like the gradient shard it corrects.

    ZeRO-sharded plans come in two dataflows (``MemoryPlan.zero_stage``):
    "zero2" gathers the bf16 param shards up front (full bf16 params live
    for the step; fp32 master/m/v and the synced grad stay shard-resident)
    and reduce-scatters gradients post-AD; "zero3" (default) gathers each
    chunk just-in-time inside the layer scan through
    ``dist.collectives.gather_param_lazy`` — a custom-vjp all-gather whose
    transpose *is* the compressed reduce-scatter, so sharded leaves' grads
    (and their new EF residuals) arrive shard-sized straight out of AD, full
    params never coexist, and ``n_buffer`` regains its xla-path meaning
    (buffered chunks keep gathered weights FWD->BWD, unbuffered ones
    re-gather in BWD). In every kind the per-microbatch sync collapses
    gradients to shard size before accumulation — the carry is shard-sized.

Dataflow diagrams and eligibility rules: docs/architecture.md §2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import optimization_barrier, shard_map
from repro.dist import collectives as COLL
from repro.dist import sharding as SH
from repro.models.layers import ParamDef

_is_def = lambda x: isinstance(x, ParamDef)  # noqa: E731
_is_sds = lambda x: isinstance(x, jax.ShapeDtypeStruct)  # noqa: E731


# ---------------------------------------------------------------------------
# Shared accumulate skeleton (both sync paths, all manual kinds)
# ---------------------------------------------------------------------------
def accumulate_grads(micro_grad, batch, microbatch, ef, acc_like, pin=None,
                     overlap=False):
    """Microbatch gradient accumulation, shared by every sync strategy.

    ``micro_grad(mb_batch, ef) -> (grads, total, ce, ef)`` computes one
    microbatch's gradients — already synced for the manual strategies (the
    "zero3" kind reduce-scatters them *inside* AD via the lazy-gather VJP) —
    threading the EF residual so each wire transmission feeds its
    quantization error back into the next. ``acc_like`` shapes the
    accumulation carry: the manual ZeRO kinds pass the *local* state params
    (shard-sized leaves), because each microbatch's grads collapse to shard
    size before they are accumulated. ``pin`` re-asserts gradient shardings
    on the carry (omitted inside shard_map).

    ``overlap`` defers each microbatch's accumulate by one iteration:
    iteration m folds microbatch m-1's *already-synced* grads into the
    accumulator while microbatch m's reduce-scatter is still draining, so
    the sync's only consumer is the loop carry and the collective can hide
    under the next microbatch's backward (docs/cost_model.md §2). The adds
    are the serial path's exact fp32 adds, shifted one iteration — numerics
    are bit-identical. Returns ``(grads, total, ce, ef)``."""
    pin = pin if pin is not None else (lambda g: g)
    if microbatch == 1:
        grads, total, ce, ef = micro_grad(batch, ef)
        return pin(grads), total, ce, ef

    def split(x):
        return x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])

    micro = jax.tree.map(split, batch)
    zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), acc_like))

    if overlap:

        def acc_body(carry, mb_batch):
            g_acc, g_pend, l_acc, ef_c = carry
            g, tot, _ce, ef_c = micro_grad(mb_batch, ef_c)
            g = pin(g)
            # Fold the *previous* microbatch's synced grads; this
            # microbatch's tree only flows into the carry, off the critical
            # path. The barrier pairs the fresh tree with the fold so at
            # most one synced tree is ever pending (the double-buffer idiom
            # from serve/paging).
            g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g_pend)
            g_pend = jax.tree.map(lambda a, b: b.astype(a.dtype), g_acc, g)
            g_pend, _ = optimization_barrier((g_pend, g_acc))
            return (g_acc, g_pend, l_acc + tot, ef_c), None

        (g_acc, g_pend, total, ef), _ = jax.lax.scan(
            acc_body, (zeros, zeros, jnp.zeros((), jnp.float32), ef), micro)
        grads = jax.tree.map(lambda a, b: a + b, g_acc, g_pend)
    else:

        def acc_body(carry, mb_batch):
            g_acc, l_acc, ef_c = carry
            g, tot, _ce, ef_c = micro_grad(mb_batch, ef_c)
            g = pin(g)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (g_acc, l_acc + tot, ef_c), None

        (grads, total, ef), _ = jax.lax.scan(
            acc_body, (zeros, jnp.zeros((), jnp.float32), ef), micro)
    grads = pin(jax.tree.map(lambda g: g / microbatch, grads))
    return grads, total / microbatch, total / microbatch, ef


# ---------------------------------------------------------------------------
# Per-leaf sync descriptors
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LeafSync:
    """How the manual path syncs one gradient leaf: ``dim`` is the
    ZeRO-sharded dim (reduce-scatter to shard owners) or None (replicated —
    DDP-style gather sync)."""
    dim: int | None


def leaf_sync_tree(spec_tree, sync_axes: tuple[str, ...]):
    """LeafSync descriptors for a ShapeDtypeStruct (or sharding) pytree."""

    def one(leaf) -> LeafSync:
        sh = getattr(leaf, "sharding", leaf)
        if not isinstance(sh, NamedSharding):
            return LeafSync(None)
        return LeafSync(SH.leaf_sync_dim(sh, sync_axes))

    return jax.tree.map(
        one, spec_tree,
        is_leaf=lambda x: isinstance(x, (NamedSharding, jax.ShapeDtypeStruct)),
    )


def manual_tree_sync(grads, errs, axis_names, compress: str, leaf_syncs):
    """Leaf-wise manual sync of one microbatch's local grad tree, dispatching
    per leaf between the gather-based all-reduce (replicated leaves) and the
    reduce-scatter (ZeRO-sharded leaves). Returns ``(synced, new_errs)``;
    uncompressed modes pass the error tree through unchanged."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_ls = treedef.flatten_up_to(leaf_syncs)
    if compress == "int8_ef":
        flat_e = treedef.flatten_up_to(errs)
        outs = []
        for g, e, ls in zip(flat_g, flat_e, flat_ls):
            if ls.dim is None:
                outs.append(COLL.manual_int8_ef_sync(g, e, axis_names))
            else:
                outs.append(
                    COLL.manual_int8_ef_reduce_scatter(g, e, axis_names, ls.dim))
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )

    def one(g, ls):
        if ls.dim is None:
            sync = (COLL.manual_bf16_mean if compress == "bf16"
                    else COLL.manual_mean)
            return sync(g, axis_names)
        rs = (COLL.manual_bf16_reduce_scatter if compress == "bf16"
              else COLL.manual_reduce_scatter)
        return rs(g, axis_names, ls.dim)

    return (
        treedef.unflatten([one(g, ls) for g, ls in zip(flat_g, flat_ls)]),
        errs,
    )


def _local_sq(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------
class XlaSync:
    """GSPMD owns the reduce; compression is wire numerics on reduced grads.

    Also serves as the 1-device fallback for manually-eligible plans: on one
    device the collective *is* the local math, so the xla body with the same
    numerics is bit-identical (same guard policy as the mesh-size checks in
    dist/collectives.py)."""

    manual_active = False

    def __init__(self, plan, mesh):
        self.plan = plan
        self.mesh = mesh
        self.compress = plan.grad_compress

    def ef_state(self, o_defs_one, g_shard):
        """(specs, shardings) of the EF residual state, or None. The xla
        residual is param-shaped fp32, sharded exactly like the grads."""
        if self.compress != "int8_ef":
            return None
        return SH.tree_specs(o_defs_one, g_shard), g_shard

    def finalize_grads(self, grads, ef, pin, ef_shard):
        """Post-accumulation wire numerics. Returns (grads, new_ef, metrics)."""
        from repro.optim.adam import global_norm

        metrics: dict[str, Any] = {}
        new_ef = None
        if self.compress == "int8_ef":
            grads, new_ef = COLL.compressed_tree_all_reduce(grads, ef)
            grads = pin(grads)
            new_ef = jax.tree.map(
                jax.lax.with_sharding_constraint, new_ef, ef_shard)
            metrics["ef_norm"] = global_norm(new_ef)
        elif self.compress == "bf16":
            grads = pin(COLL.bf16_tree_all_reduce(grads))
        return grads, new_ef, metrics


class ManualSync:
    """The whole step body under shard_map; dist/collectives own the wire.

    ``kind`` is ``MemoryPlan.manual_sync_kind``'s verdict ("ddp" | "zero2" |
    "zero3"); the per-leaf descriptors make the kinds one code path — a "ddp"
    plan simply has no sharded leaves, so its gather is the identity and
    every leaf takes the all-gather sync. The two ZeRO kinds differ only in
    *when* params are gathered:

      * "zero2" all-gathers every sharded bf16 leaf up front and keeps the
        full tree live for the step; gradients reduce-scatter post-AD
        (``manual_tree_sync``).
      * "zero3" never materializes the full tree: the loss closure (built by
        step_builder.make_lazy_loss_fn) gathers each chunk just-in-time
        inside the layer scan via ``dist.collectives.gather_param_lazy``,
        whose VJP *is* the compressed reduce-scatter — sharded leaves' grads
        arrive shard-sized straight out of AD, and the new EF residuals come
        out as the "gradient" w.r.t. the residual inputs. Only replicated
        leaves still sync post-AD (DDP-style). ``n_buffer`` keeps its
        xla-path meaning: buffered chunks save gathered weights FWD->BWD,
        unbuffered ones re-gather in BWD through the remat policy.
    """

    manual_active = True

    def __init__(self, plan, mesh, kind: str):
        self.plan = plan
        self.mesh = mesh
        self.kind = kind
        self.compress = plan.grad_compress
        # ZeRO kinds sync over the ZeRO (param-shard) axes so the
        # reduce-scatter owner coordinate matches the storage layout;
        # eligibility pins tp_degree == 1, making them the full batch extent
        # either way.
        self.axes = (SH.zero_axes(mesh) if kind in ("zero2", "zero3")
                     else SH.manual_sync_axes(mesh, plan.dp_only))
        sizes = SH.mesh_sizes(mesh)
        self.n_sync = math.prod(sizes[a] for a in self.axes)

    # -- EF residual state layout -------------------------------------------
    def ef_state(self, o_defs_one, g_shard):
        """Manual EF is device-varying state. Replicated leaves store it
        stacked — leading axis ``n_sync``, sharded over the sync axes — so
        checkpoints see the true per-device residuals. ZeRO-sharded leaves
        store one fp32 array in the *gradient's own sharded layout*: each
        device's residual is the shard it owns, so per-device bytes are
        shard-sized and the global view is directly checkpointable."""
        if self.compress != "int8_ef":
            return None
        stacked_ps = SH.manual_batch_pspec(1, self.mesh, self.plan.dp_only)

        def spec(d: ParamDef, s: NamedSharding):
            if SH.leaf_sync_dim(s, self.axes) is not None:
                return jax.ShapeDtypeStruct(d.shape, jnp.float32, sharding=s)
            return jax.ShapeDtypeStruct(
                (self.n_sync,) + d.shape, jnp.float32,
                sharding=NamedSharding(self.mesh, stacked_ps))

        specs = jax.tree.map(spec, o_defs_one, g_shard, is_leaf=_is_def)
        shardings = jax.tree.map(lambda s: s.sharding, specs, is_leaf=_is_sds)
        return specs, shardings

    # -- step construction ---------------------------------------------------
    def build_step_fn(self, *, loss, apply_update, state_specs, batch_specs,
                      global_batch: int, microbatch: int, lazy_loss=None):
        """Assemble the shard_map'd step. ``loss`` must be the manual-mode
        loss closure (identity activation sharder, fully-gathered params —
        see step_builder.make_loss_fn); for the "zero3" kind ``lazy_loss`` is
        the per-chunk-gather closure ``(params, ef, batch) -> (total, ce)``
        (step_builder.make_lazy_loss_fn) and ``loss`` is unused.
        ``apply_update`` is the shared optimizer/assembly tail."""
        axes, n_sync, compress, kind = self.axes, self.n_sync, self.compress, self.kind
        local_b = global_batch // max(n_sync, 1)
        if global_batch % n_sync or (microbatch > 1 and local_b % microbatch):
            raise ValueError(
                "manual sync splits the per-device batch shard into "
                f"microbatches: global_batch={global_batch} must divide "
                f"by sync extent {n_sync} (and the local batch {local_b} by "
                f"microbatch={microbatch})"
            )
        if kind == "zero3" and lazy_loss is None:
            raise ValueError("manual 'zero3' sync needs the lazy-gather loss "
                             "closure (step_builder.make_lazy_loss_fn)")
        leafs = leaf_sync_tree(state_specs["params"], axes)
        has_sharded = any(ls.dim is not None for ls in jax.tree.leaves(
            leafs, is_leaf=lambda x: isinstance(x, LeafSync)))

        def gather_full(params):
            """Up-front all-gather of ZeRO-sharded bf16 param shards to full
            leaves ("zero2"; identity for "ddp" plans: no sharded leaves)."""

            def one(w, ls: LeafSync):
                if ls.dim is None:
                    return w
                return jax.lax.all_gather(w, axes, axis=ls.dim, tiled=True)

            return jax.tree.map(one, params, leafs)

        def replicated_sync(g, ee, eg, ls):
            """Post-AD sync of one replicated leaf; sharded leaves were
            already reduce-scattered inside AD (zero3), whose new residual is
            ``eg`` — the loss's "gradient" w.r.t. the residual input."""
            if ls.dim is not None:
                return g, eg
            if compress == "int8_ef":
                return COLL.manual_int8_ef_sync(g, ee, axes)
            sync = COLL.manual_bf16_mean if compress == "bf16" else COLL.manual_mean
            return sync(g, axes), ee

        def split_ef(ef):
            """Global EF view -> this device's local residuals (stacked
            leaves carry a size-1 leading slice; sharded leaves arrive as
            the owned shard already)."""
            return jax.tree.map(
                lambda e, ls: e if ls.dim is not None else e[0], ef, leafs)

        def stack_ef(ef):
            return jax.tree.map(
                lambda e, ls: e if ls.dim is not None else e[None], ef, leafs)

        def grad_norm(grads):
            """Global gradient norm: sharded leaves hold disjoint shards
            (their squared sums add across devices); replicated leaves are
            identical everywhere (count once)."""
            flat_g, treedef = jax.tree.flatten(grads)
            flat_ls = treedef.flatten_up_to(leafs)
            sq_shard = sum(
                (jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g, ls in zip(flat_g, flat_ls) if ls.dim is not None),
                start=jnp.zeros((), jnp.float32))
            sq_rep = sum(
                (jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g, ls in zip(flat_g, flat_ls) if ls.dim is None),
                start=jnp.zeros((), jnp.float32))
            if has_sharded:
                sq_shard = jax.lax.psum(sq_shard, axes)
            return jnp.sqrt(sq_shard + sq_rep)

        def body(state, batch):
            ef = split_ef(state["ef"]) if compress == "int8_ef" else None
            if kind == "zero3":
                # per-chunk lazy gather: sharded leaves' grads (and new EF
                # residuals) come out of AD already reduce-scattered; only
                # replicated leaves need the post-AD DDP-style sync
                def micro_grad(mb_batch, ef_c):
                    if compress == "int8_ef":
                        (tot, ce), (g, ef_g) = jax.value_and_grad(
                            lazy_loss, argnums=(0, 1), has_aux=True)(
                                state["params"], ef_c, mb_batch)
                        flat_g, td = jax.tree.flatten(g)
                        outs = [replicated_sync(gg, ee, eg, ls)
                                for gg, ee, eg, ls in zip(
                                    flat_g, td.flatten_up_to(ef_c),
                                    td.flatten_up_to(ef_g),
                                    td.flatten_up_to(leafs))]
                        return (td.unflatten([o[0] for o in outs]), tot, ce,
                                td.unflatten([o[1] for o in outs]))
                    (tot, ce), g = jax.value_and_grad(
                        lazy_loss, has_aux=True)(state["params"], None, mb_batch)
                    flat_g, td = jax.tree.flatten(g)
                    synced = [replicated_sync(gg, None, None, ls)[0]
                              for gg, ls in zip(flat_g, td.flatten_up_to(leafs))]
                    return td.unflatten(synced), tot, ce, ef_c
            else:
                full_params = gather_full(state["params"])

                def micro_grad(mb_batch, ef_c):
                    (tot, ce), g = jax.value_and_grad(
                        loss, has_aux=True)(full_params, mb_batch)
                    g, ef_c = manual_tree_sync(g, ef_c, axes, compress, leafs)
                    return g, tot, ce, ef_c

            grads, total, ce, ef = accumulate_grads(
                micro_grad, batch, microbatch, ef, acc_like=state["params"],
                overlap=self.plan.overlap)

            # losses were computed on the local batch shard; average them
            total = jax.lax.pmean(total, axes)
            ce = jax.lax.pmean(ce, axes)

            metrics: dict[str, Any] = {}
            new_ef = None
            if compress == "int8_ef":
                # global residual norm: per-device values differ, so reduce
                # the squared sums for a replicated metric
                metrics["ef_norm"] = jnp.sqrt(jax.lax.psum(_local_sq(ef), axes))
                new_ef = stack_ef(ef)

            return apply_update(state, grads, total, ce, new_ef, metrics,
                                host_plan=None, repin=False,
                                grad_norm=grad_norm(grads))

        state_ps = SH.manual_state_pspecs(state_specs)
        batch_ps = jax.tree.map(
            lambda s: SH.manual_batch_pspec(
                len(s.shape), self.mesh, self.plan.dp_only),
            batch_specs, is_leaf=_is_sds,
        )
        metric_names = ["loss", "ce", "grad_norm", "lr"] + (
            ["ef_norm"] if compress == "int8_ef" else [])
        metrics_ps = {k: P() for k in metric_names}
        # replication check off: the checker cannot see that a gather-based
        # all-reduce (all_gather + identical local mean) yields replicated
        # outputs; replication holds by construction (dist/collectives.py)
        return shard_map(body, self.mesh, in_specs=(state_ps, batch_ps),
                         out_specs=(state_ps, metrics_ps), check=False)


def make_strategy(plan, mesh, tp_degree: int) -> XlaSync | ManualSync:
    """Sync strategy for a plan on a mesh; raises for ineligible manual plans.

    Structural eligibility is validated even on 1-device meshes (code first
    exercised locally fails the same way it would deployed); the 1-device
    *fallback* to the local-math xla strategy only applies to plans that
    could lower manually in the first place."""
    if plan.sync_mode != "manual":
        return XlaSync(plan, mesh)
    kind = plan.manual_sync_kind(tp_degree)
    if kind is None:
        raise ValueError(
            "sync_mode='manual' requires a layout the shard_map body can "
            "lower: no swap blocks, no host-resident chunks, no "
            "zero1_persistent, and tp_degree == 1 (all-persist 'ddp' plans "
            "may instead set dp_only to absorb the model axis). Got "
            f"{plan.describe()} on tp_degree={tp_degree}. "
            "See MemoryPlan.manual_sync_kind / docs/architecture.md."
        )
    if math.prod(mesh.devices.shape) == 1:
        return XlaSync(plan, mesh)
    return ManualSync(plan, mesh, kind)


# ---------------------------------------------------------------------------
# Telemetry: static per-step wire-byte inventory
# ---------------------------------------------------------------------------
def record_sync_inventory(strategy, params_specs, microbatch: int,
                          registry=None) -> dict[str, int]:
    """Record the step's collective wire-byte inventory as gauges.

    Collectives execute inside jit, so runtime counters cannot observe them
    — the traced program runs the Python body exactly once. What *is* known
    statically is the payload each strategy puts on the wire per step, and
    that is what this records, from the parameter leaf specs:

      * ``sync.wire_bytes_per_step{strategy=..., op=grad_sync}`` — the
        gradient sync payload: every param leaf at the compression payload
        width (1 B int8_ef / 2 B bf16 / 4 B fp32), once per step (sync
        happens after microbatch accumulation).
      * ``sync.wire_bytes_per_step{strategy=..., op=param_gather}`` — bf16
        param-gather traffic of ZeRO kinds: zero2 gathers sharded leaves
        once up front; zero3 re-gathers inside the scan every microbatch.
      * ``sync.wire_payload{strategy=...}`` — the payload element width.

    Logical payload bytes, not per-link ring traffic (multiply by
    (n-1)/n per hop for that). Resolves the registry through
    ``obs.current_telemetry()`` when not given; with none installed this
    only builds the (small) returned dict.
    """
    from repro import obs

    reg = registry if registry is not None else obs.current_telemetry().registry
    kind = getattr(strategy, "kind", "xla")
    compress = strategy.compress
    itemsize = {"int8_ef": 1, "bf16": 2}.get(compress, 4)
    axes = getattr(strategy, "axes", ())

    grad_bytes = 0
    gather_bytes = 0
    for leaf in jax.tree.leaves(params_specs):
        n = math.prod(leaf.shape)
        grad_bytes += n * itemsize
        if kind in ("zero2", "zero3"):
            sh = getattr(leaf, "sharding", None)
            if isinstance(sh, NamedSharding) and \
                    SH.leaf_sync_dim(sh, axes) is not None:
                gather_bytes += n * 2  # bf16 gather payload
    if kind == "zero3":
        gather_bytes *= microbatch
    inv = {"grad_sync": grad_bytes, "param_gather": gather_bytes,
           "payload_itemsize": itemsize}
    reg.gauge("sync.wire_bytes_per_step", strategy=kind,
              op="grad_sync").set(grad_bytes)
    reg.gauge("sync.wire_bytes_per_step", strategy=kind,
              op="param_gather").set(gather_bytes)
    reg.gauge("sync.wire_payload", strategy=kind).set(itemsize)
    return inv
