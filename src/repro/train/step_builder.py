"""Build jit-able train/serve steps that realize a MemoryPlan.

This is where ProTrain's plan becomes an XLA program:

  * chunk placement  -> per-run parameter NamedShardings (persist = replicated
    over ZeRO axes; hbm = sharded; host = sharded + pinned_host memory kind)
  * n_buffer         -> gathered-weight save policy (re-gather in BWD or not)
  * block policies   -> per-position jax.checkpoint policies (keep / remat /
    host-offload / quantize-on-save): plan.block_policy(b) — the scalar
    n_swap/n_ckpt prefixes or the explicit act_policies vector — splits the
    layer stack into runs, one policy per run
  * microbatch       -> gradient-accumulation scan
  * host_optimizer   -> optimizer states of host chunks live in pinned_host
  * sync_mode        -> who owns the gradient reduction; lowered through the
    strategy objects in train/sync.py: "xla" (GSPMD inserts it; grad_compress
    applies wire numerics to the reduced grads) or "manual" (the whole step
    body runs under shard_map with in/out specs from dist/sharding.py and the
    compressed payload crosses the wire — DDP-style gather sync for
    replicated layouts, compressed reduce-scatter for ZeRO-sharded ones; see
    docs/architecture.md for the dataflows and eligibility rules)

The returned artifacts carry ShapeDtypeStruct specs for every input so the
multi-pod dry-run can ``.lower().compile()`` without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.plan import MemoryPlan
from repro.dist import collectives as COLL
from repro.dist import sharding as SH
from repro.models import kvcache as KV
from repro.models import model as M
from repro.models.layers import ParamDef
from repro.optim import adam as OPT
from repro.train import sync as SYNC
from repro.train.losses import chunked_cross_entropy


# ---------------------------------------------------------------------------
# Plan -> run layout
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RunLayout:
    start: int  # first superblock repeat (== chunk index - 1)
    length: int
    placement: str  # persist | hbm | host
    buffered: bool
    act_policy: str  # none | checkpoint | swap | compress8 | compress16


def plan_runs(plan: MemoryPlan, n_repeats: int) -> list[RunLayout]:
    runs: list[RunLayout] = []
    for r in range(n_repeats):
        chunk = r + 1  # chunk 0 is the embedding
        key = (
            plan.chunk_placement(chunk),
            plan.chunk_buffered(chunk),
            plan.block_policy(min(r, plan.n_blocks - 1)),
        )
        if runs and (runs[-1].placement, runs[-1].buffered, runs[-1].act_policy) == key:
            runs[-1].length += 1
        else:
            runs.append(RunLayout(r, 1, *key))
    return runs


def _slice_run_defs(block_defs, length: int):
    """Stacked (R, ...) ParamDefs -> (length, ...) defs for one run."""
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=(length,) + d.shape[1:]),
        block_defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _per_repeat_defs(block_defs):
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=d.shape[1:], axes=d.axes[1:]),
        block_defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


# ---------------------------------------------------------------------------
# Step artifacts
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StepArtifacts:
    fn: Callable  # (state, batch) -> (state, metrics)   [or serve variants]
    state_specs: Any  # ShapeDtypeStruct pytree (with shardings)
    batch_specs: Any
    state_shardings: Any
    batch_shardings: Any
    plan: MemoryPlan
    runs: list[RunLayout]
    init: Callable | None = None  # (key) -> state, concrete (small models)

    def lower(self, donate: bool = True):
        jfn = jax.jit(self.fn, donate_argnums=(0,) if donate else ())
        return jfn.lower(self.state_specs, self.batch_specs)


def _opt_placement(placement: str, plan: MemoryPlan) -> str:
    """Optimizer-state placement for a chunk placement."""
    if placement == "persist":
        return "zero1" if plan.zero1_persistent else "persist"
    return placement


def _opt_sharding(d: ParamDef, mesh, placement: str, plan: MemoryPlan) -> NamedSharding:
    op = _opt_placement(placement, plan)
    if op == "zero1":
        return SH.sharding_for(d, mesh, placement="hbm", dp_only=plan.dp_only)
    return SH.sharding_for(d, mesh, placement=op, dp_only=plan.dp_only)


def build_train_step(
    cfg: ModelConfig,
    plan: MemoryPlan,
    mesh,
    shape: ShapeConfig,
    *,
    adam: OPT.AdamConfig | None = None,
    attn_impl: str = "blockwise",
    ce_chunk: int = 2048,
    lr_schedule: Callable | None = None,
) -> StepArtifacts:
    adam = adam or OPT.AdamConfig()
    period = M.superblock_period(cfg)
    n_rep = M.num_repeats(cfg)
    runs_layout = plan_runs(plan, n_rep)
    defs = M.param_defs(cfg)
    head_chunk = plan.chunk_placement(plan.n_chunks - 1)
    embed_chunk = plan.chunk_placement(0)
    dp = plan.dp_only

    def param_place(pl: str) -> str:
        # ZeRO-Offload split: bf16 params stay in HBM; only opt states go host
        return "hbm" if (pl == "host" and not plan.host_params) else pl

    head_pchunk = param_place(head_chunk)
    embed_pchunk = param_place(embed_chunk)

    # --- parameter defs & shardings, organized by run ----------------------
    p_defs: dict[str, Any] = {
        "embed": defs["embed"],
        "final_norm": defs["final_norm"],
        "runs": [_slice_run_defs(defs["blocks"], r.length) for r in runs_layout],
    }
    if "head" in defs:
        p_defs["head"] = defs["head"]
    if "encoder" in defs:
        p_defs["encoder"] = defs["encoder"]

    p_shard: dict[str, Any] = {
        "embed": SH.tree_shardings(defs["embed"], mesh, placement=embed_pchunk, dp_only=dp),
        "final_norm": SH.tree_shardings(defs["final_norm"], mesh, placement=head_pchunk, dp_only=dp),
        "runs": [
            SH.tree_shardings(p_defs["runs"][i], mesh, placement=param_place(r.placement), dp_only=dp)
            for i, r in enumerate(runs_layout)
        ],
    }
    if "head" in defs:
        p_shard["head"] = SH.tree_shardings(defs["head"], mesh, placement=head_pchunk, dp_only=dp)
    if "encoder" in defs:
        p_shard["encoder"] = SH.tree_shardings(defs["encoder"], mesh, placement=embed_pchunk, dp_only=dp)

    # --- optimizer state shardings (fp32 master/m/v) ------------------------
    def opt_tree(fn_placement):
        out = {
            "embed": jax.tree.map(
                lambda d: fn_placement(d, embed_chunk), defs["embed"],
                is_leaf=lambda x: isinstance(x, ParamDef)),
            "final_norm": jax.tree.map(
                lambda d: fn_placement(d, head_chunk), defs["final_norm"],
                is_leaf=lambda x: isinstance(x, ParamDef)),
            "runs": [
                jax.tree.map(lambda d, _r=r: fn_placement(d, _r.placement), p_defs["runs"][i],
                             is_leaf=lambda x: isinstance(x, ParamDef))
                for i, r in enumerate(runs_layout)
            ],
        }
        if "head" in defs:
            out["head"] = jax.tree.map(lambda d: fn_placement(d, head_chunk), defs["head"],
                                       is_leaf=lambda x: isinstance(x, ParamDef))
        if "encoder" in defs:
            out["encoder"] = jax.tree.map(lambda d: fn_placement(d, embed_chunk), defs["encoder"],
                                          is_leaf=lambda x: isinstance(x, ParamDef))
        return out

    def fp32_def(d: ParamDef) -> ParamDef:
        return dataclasses.replace(d, dtype="float32")

    o_shard_one = opt_tree(lambda d, pl: _opt_sharding(d, mesh, pl, plan))
    o_defs_one = jax.tree.map(fp32_def, p_defs, is_leaf=lambda x: isinstance(x, ParamDef))
    opt_defs = {"master": o_defs_one, "m": o_defs_one, "v": o_defs_one}
    opt_shard = {"master": o_shard_one, "m": o_shard_one, "v": o_shard_one}

    # host-offloaded leaves: (param shard, opt host shard, opt device shard)
    def host_entry(d: ParamDef, pl: str):
        if pl != "host" or not plan.host_optimizer:
            return None
        df = fp32_def(d)
        return (
            SH.sharding_for(d, mesh, placement=param_place("host"), dp_only=dp),
            SH.sharding_for(df, mesh, placement="host", dp_only=dp),
            SH.sharding_for(df, mesh, placement="hbm", dp_only=dp),
        )

    host_plan_flat = [
        host_entry(d, pl)
        for d, pl in zip(
            jax.tree.leaves(p_defs, is_leaf=lambda x: isinstance(x, ParamDef)),
            jax.tree.leaves(
                opt_tree(lambda d, pl: pl), is_leaf=lambda x: isinstance(x, str)
            ),
        )
    ]

    state_specs = {
        "params": SH.tree_specs(p_defs, p_shard),
        "opt": {
            **{k: SH.tree_specs(opt_defs[k], opt_shard[k]) for k in ("master", "m", "v")},
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_shardings = {
        "params": p_shard,
        "opt": {**opt_shard, "count": NamedSharding(mesh, P())},
        "step": NamedSharding(mesh, P()),
    }

    # --- batch specs ---------------------------------------------------------
    bsh = SH.batch_sharding(mesh, 2, dp_only=dp)
    gb, sl = shape.global_batch, shape.seq_len
    batch_specs: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((gb, sl), jnp.int32, sharding=bsh),
        "labels": jax.ShapeDtypeStruct((gb, sl), jnp.int32, sharding=bsh),
    }
    bsh3 = SH.batch_sharding(mesh, 3, dp_only=dp)
    if cfg.kind == "encdec":
        batch_specs["frames"] = jax.ShapeDtypeStruct(
            (gb, sl, cfg.d_model), jnp.dtype(cfg.dtype), sharding=bsh3
        )
    if cfg.frontend == "vision_patches":
        n_patch = min(1024, sl)
        batch_specs["patches"] = jax.ShapeDtypeStruct(
            (gb, n_patch, cfg.d_model), jnp.dtype(cfg.dtype), sharding=bsh3
        )
    batch_shardings = jax.tree.map(lambda s: s.sharding, batch_specs,
                                   is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    # --- gather specs per run (point-of-use all-gather) ---------------------
    per_rep = _per_repeat_defs(defs["blocks"])
    gather_specs = [
        SH.tree_gather_shardings(defs["blocks"], mesh,
                                 persistent=r.placement == "persist", dp_only=dp)
        for r in runs_layout
    ]
    enc_gather = None
    if "encoder" in defs:
        enc_gather = SH.tree_gather_shardings(
            defs["encoder"]["blocks"], mesh, persistent=embed_chunk == "persist",
            dp_only=dp,
        )

    # Non-run parameter groups (embed / final_norm / head / encoder norm) need
    # an explicit device fetch when host-placed (and an explicit gather point
    # for the sharded head); runs handle this inside gather_weights.
    def _fetch_specs(subtree_defs, placement, force=False):
        if placement != "host" and not force:
            return None
        return jax.tree.map(lambda d: SH.gather_sharding(d, mesh, dp_only=dp), subtree_defs,
                            is_leaf=lambda x: isinstance(x, ParamDef))

    fetch_specs = {
        "embed": _fetch_specs(defs["embed"], embed_pchunk),
        "final_norm": _fetch_specs(defs["final_norm"], head_pchunk),
    }
    if "head" in defs:
        fetch_specs["head"] = _fetch_specs(defs["head"], head_pchunk,
                                           force=head_pchunk != "persist")
    if "encoder" in defs:
        fetch_specs["encoder_final_norm"] = _fetch_specs(
            defs["encoder"]["final_norm"], embed_pchunk)

    def fetch(params):
        out = dict(params)
        for key in ("embed", "final_norm", "head"):
            spec = fetch_specs.get(key)
            if spec is not None and key in out:
                out[key] = jax.tree.map(jax.device_put, out[key], spec)
        if fetch_specs.get("encoder_final_norm") is not None:
            enc = dict(out["encoder"])
            enc["final_norm"] = jax.tree.map(
                jax.device_put, enc["final_norm"], fetch_specs["encoder_final_norm"]
            )
            out["encoder"] = enc
        return out

    sharder = SH.make_activation_sharder(mesh, plan)

    def make_runs(params, full: bool = False) -> list[M.Run]:
        """``full=True`` (manual sync): params were gathered to full leaves
        before the loss, so every run behaves persistent — no point-of-use
        device_put gathers (they cannot appear inside a shard_map body)."""
        return [
            M.Run(
                params=params["runs"][i],
                n_repeats=r.length,
                act_policy=r.act_policy,
                buffered=True if full else r.buffered,
                persistent=True if full else r.placement == "persist",
                gather_specs=None if full else gather_specs[i],
                ckpt_group=plan.ckpt_group,
            )
            for i, r in enumerate(runs_layout)
        ]

    # sharding for the CE head-grad accumulator (see losses.py): matches the
    # head weight as it enters the loss (gathered over ZeRO, sharded over TP)
    zero_axes = SH.batch_axes(mesh, dp)
    tp_axis = None if dp else ("model" if "model" in mesh.axis_names else None)
    if cfg.tie_embeddings:
        w_acc_sharding = NamedSharding(mesh, P(zero_axes or None, tp_axis))
    else:
        w_acc_sharding = NamedSharding(mesh, P(None, tp_axis))

    def make_loss_fn(act_sharder, w_acc, full: bool = False):
        """Loss closure; the manual path re-instantiates it with an identity
        activation sharder, no CE-accumulator constraint (NamedShardings
        cannot name axes that are Manual inside a shard_map body), and
        ``full=True``: params arrive pre-gathered to full leaves, so the
        device_put-based fetch/gather machinery is bypassed entirely."""

        def loss_fn(params, batch):
            M.set_activation_sharder(act_sharder)
            fparams = params if full else fetch(params)
            if not full and plan.overlap:
                # overlap the loss-head fetches with the layer scan: the
                # final_norm/head device_puts (host upload and/or ZeRO
                # gather) are consumed only after the scan, so left alone
                # XLA may sink them to the loss head and pay their latency
                # serially. Bundling them with the embed subtree orders the
                # fetches at program start — in flight during the whole
                # forward — without delaying the scan (which reads only the
                # un-barriered run params).
                keys = [k for k in ("final_norm", "head")
                        if fetch_specs.get(k) is not None and k in fparams]
                if keys:
                    bundled, _ = optimization_barrier(
                        ({k: fparams[k] for k in keys}, fparams["embed"]))
                    fparams = {**fparams, **bundled}
            h, aux = M.forward(
                fparams, batch, cfg, runs=make_runs(params, full=full),
                attn_impl=attn_impl,
                encoder_gather_specs=None if full else enc_gather,
            )
            from repro.models.layers import apply_norm

            h = M.shard_act(h, "enter")  # SP: back to batch-only for the CE scan
            h = apply_norm(fparams["final_norm"], h, cfg.norm)
            w = fparams["embed"]["tok"].T if cfg.tie_embeddings else fparams["head"]["w"]
            loss = chunked_cross_entropy(
                h, w, batch["labels"], ce_chunk=ce_chunk, w_acc_sharding=w_acc
            )
            return loss + aux.astype(jnp.float32), loss

        return loss_fn

    loss_fn = make_loss_fn(sharder, w_acc_sharding)

    def make_lazy_loss_fn(strategy):
        """Manual "zero3" loss closure: per-chunk lazy gather hooks instead
        of a pre-gathered param tree. Sharded leaves route through
        ``dist.collectives.gather_param_lazy`` — run (block) leaves inside
        the layer scan (one chunk's full weights at a time, remat policy
        deciding FWD->BWD buffering per the plan's ``n_buffer``), non-run
        groups (embed / head / encoder — each its own chunk) at their point
        of use. The EF residual tree rides along as a loss *input* whose
        "gradient" is the new residual (see gather_param_lazy)."""
        axes, compress = strategy.axes, plan.grad_compress
        leafs_tree = SYNC.leaf_sync_tree(state_specs["params"], axes)
        _is_ls = lambda x: isinstance(x, SYNC.LeafSync)  # noqa: E731

        def per_repeat_ls(ls_tree):
            # stacked run leaves carry the LAYER axis first; the scan slices
            # it off, so the per-repeat shard dim is the stacked dim - 1
            return jax.tree.map(
                lambda ls: SYNC.LeafSync(None if ls.dim is None else ls.dim - 1),
                ls_tree, is_leaf=_is_ls)

        def subtree_gather(pp, epp, ls_sub, name=False, anchor=None):
            flat_w, td = jax.tree.flatten(pp)
            flat_ls = td.flatten_up_to(ls_sub)
            flat_e = (td.flatten_up_to(epp) if epp is not None
                      else [None] * len(flat_w))
            out = []
            for w, ls, e in zip(flat_w, flat_ls, flat_e):
                if ls.dim is None:
                    out.append(w)
                    continue
                g = COLL.gather_param_lazy(w, e, axes, ls.dim, compress,
                                           anchor=anchor)
                out.append(checkpoint_name(g, M.GATHERED_W) if name else g)
            return td.unflatten(out)

        def make_zero3_runs(params, ef):
            out = []
            for i, r in enumerate(runs_layout):
                if r.placement == "persist":
                    out.append(M.Run(
                        params=params["runs"][i], n_repeats=r.length,
                        act_policy=r.act_policy, buffered=True,
                        persistent=True, gather_specs=None,
                        ckpt_group=plan.ckpt_group))
                    continue
                ls_rep = per_repeat_ls(leafs_tree["runs"][i])
                out.append(M.Run(
                    params=params["runs"][i], n_repeats=r.length,
                    act_policy=r.act_policy, buffered=r.buffered,
                    persistent=False, gather_specs=None,
                    ckpt_group=plan.ckpt_group,
                    lazy_gather=lambda pp, epp, j, anchor=None,
                    _ls=ls_rep: subtree_gather(
                        pp, epp, _ls[f"pos{j}"], name=True, anchor=anchor),
                    ef=None if ef is None else ef["runs"][i],
                    # double-buffered gather prefetch (model.apply_runs):
                    # active only for buffered runs under an overlap plan
                    # with n_buffer >= 2 — everything else keeps the serial
                    # inline gather
                    prefetch=plan.gather_prefetch_depth >= 2,
                ))
            return out

        def lazy_loss(params, ef, batch):
            M.set_activation_sharder(lambda x, kind="bsd": x)
            fparams = dict(params)
            for key in ("embed", "final_norm", "head", "encoder"):
                if key in fparams:
                    fparams[key] = subtree_gather(
                        fparams[key], None if ef is None else ef[key],
                        leafs_tree[key])
            h, aux = M.forward(
                fparams, batch, cfg, runs=make_zero3_runs(params, ef),
                attn_impl=attn_impl, encoder_gather_specs=None,
            )
            from repro.models.layers import apply_norm

            h = M.shard_act(h, "enter")
            h = apply_norm(fparams["final_norm"], h, cfg.norm)
            w = fparams["embed"]["tok"].T if cfg.tie_embeddings else fparams["head"]["w"]
            loss = chunked_cross_entropy(
                h, w, batch["labels"], ce_chunk=ce_chunk, w_acc_sharding=None
            )
            return loss + aux.astype(jnp.float32), loss

        return lazy_loss

    # gradient shardings: same partitioning as params, but always in device
    # memory (host-chunk grads are reduce-scattered on device, then the
    # optimizer round-trips the states). Without this constraint the transpose
    # of the point-of-use gather leaves cotangents unsharded and XLA happily
    # materializes replicated full-model gradients.
    g_shard = jax.tree.map(
        lambda s: NamedSharding(s.mesh, s.spec), p_shard,
        is_leaf=lambda x: isinstance(x, NamedSharding),
    )

    def pin_grads(grads):
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, g_shard)

    # --- gradient sync: strategy object owns the control flow ---------------
    # train/sync.py picks the pipeline for (sync_mode, layout kind) — raising
    # for structurally-ineligible manual plans even on 1-device meshes (so
    # code first exercised locally fails the same way it would deployed) and
    # falling back to the local-math xla strategy on one device. The EF
    # residual layout is the strategy's to define: replicated-grad residuals
    # are stacked per-device, ZeRO-shard residuals live in the gradient's own
    # sharded layout.
    tp_degree = SH.mesh_sizes(mesh).get("model", 1)
    strategy = SYNC.make_strategy(plan, mesh, tp_degree)
    # telemetry (host-side, no-op without an installed handle): the step's
    # static collective wire-byte inventory — collectives run inside jit, so
    # this is recorded from the leaf specs, not counted at runtime
    SYNC.record_sync_inventory(strategy, state_specs["params"], plan.microbatch)
    compress = plan.grad_compress
    ef_layout = strategy.ef_state(o_defs_one, g_shard)
    if ef_layout is not None:
        state_specs["ef"], state_shardings["ef"] = ef_layout

    def apply_update(state, grads, total, ce, new_ef, metrics, *,
                     host_plan, repin, grad_norm=None):
        """Optimizer update + new-state/metrics assembly, shared tail of both
        step bodies (manual passes host_plan=None, repin=False: no host
        chunks exist under manual eligibility, and device_put cannot appear
        inside a shard_map body; it supplies grad_norm because its shard-
        local gradient leaves need a cross-device norm for clipping)."""
        lr = lr_schedule(state["step"]) if lr_schedule else adam.lr
        new_params, new_opt, gnorm = OPT.adam_update(
            state["params"], grads, state["opt"], adam, lr,
            host_plan=host_plan, grad_norm=grad_norm,
        )
        if repin:  # keep shardings/memory kinds pinned through the update
            new_params = jax.tree.map(jax.device_put, new_params, p_shard)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        if compress == "int8_ef":
            new_state["ef"] = new_ef
        metrics.update({"loss": total, "ce": ce, "grad_norm": gnorm, "lr": jnp.asarray(lr)})
        return new_state, metrics

    if strategy.manual_active:
        step_fn = strategy.build_step_fn(
            loss=make_loss_fn(lambda x, kind="bsd": x, None, full=True),
            lazy_loss=(make_lazy_loss_fn(strategy)
                       if strategy.kind == "zero3" else None),
            apply_update=apply_update,
            state_specs=state_specs,
            batch_specs=batch_specs,
            global_batch=shape.global_batch,
            microbatch=plan.microbatch,
        )
    else:
        def step_fn(state, batch):
            def micro_grad(mb_batch, ef_c):
                (total, ce), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], mb_batch)
                return g, total, ce, ef_c

            grads, total, ce, _ = SYNC.accumulate_grads(
                micro_grad, batch, plan.microbatch, None, state["params"],
                pin=pin_grads)
            grads, new_ef, metrics = strategy.finalize_grads(
                grads, state.get("ef"), pin_grads, g_shard)
            return apply_update(state, grads, total, ce, new_ef, metrics,
                                host_plan=host_plan_flat, repin=True)

    def init(key):
        flat_defs = p_defs
        from repro.models.layers import init_tree

        params = init_tree(flat_defs, key)
        params = jax.tree.map(jax.device_put, params, p_shard)
        opt = OPT.init_opt_state(params)
        opt = {
            "master": jax.tree.map(jax.device_put, opt["master"], opt_shard["master"]),
            "m": jax.tree.map(jax.device_put, opt["m"], opt_shard["m"]),
            "v": jax.tree.map(jax.device_put, opt["v"], opt_shard["v"]),
            "count": opt["count"],
        }
        state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
        if compress == "int8_ef":
            # zeros matching state_specs["ef"] — param-shaped replicated for
            # the xla path, stacked per-device for manual (see above)
            state["ef"] = jax.tree.map(
                lambda s: jax.device_put(jnp.zeros(s.shape, s.dtype), s.sharding),
                state_specs["ef"],
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
        # identical constants (m/v zeros, step/count scalars) may share device
        # buffers, which breaks donation ("donate the same buffer twice")
        return jax.tree.map(lambda x: x.copy(), state)

    return StepArtifacts(
        fn=step_fn,
        state_specs=state_specs,
        batch_specs=batch_specs,
        state_shardings=state_shardings,
        batch_shardings=batch_shardings,
        plan=plan,
        runs=runs_layout,
        init=init,
    )


# ---------------------------------------------------------------------------
# Serving steps (prefill / decode)
# ---------------------------------------------------------------------------
def build_serve_params(cfg: ModelConfig, plan: MemoryPlan, mesh):
    """Serving keeps weights only; plan decides persist vs gathered chunks."""
    defs = M.param_defs(cfg)
    n_rep = M.num_repeats(cfg)
    runs_layout = plan_runs(plan, n_rep)
    head_chunk = plan.chunk_placement(plan.n_chunks - 1)
    embed_chunk = plan.chunk_placement(0)
    dp = plan.dp_only
    # serving has no optimizer states: host placement == weights on host
    head_pchunk, embed_pchunk = head_chunk, embed_chunk
    p_defs = {
        "embed": defs["embed"],
        "final_norm": defs["final_norm"],
        # serving keeps the canonical stacked layout (single run per placement
        # is meaningless without buffering semantics) but honors placement
        "blocks": defs["blocks"],
    }
    blocks_placement = plan.chunk_placement(1)
    p_shard = {
        "embed": SH.tree_shardings(defs["embed"], mesh, placement=embed_pchunk, dp_only=dp),
        "final_norm": SH.tree_shardings(defs["final_norm"], mesh, placement=head_pchunk, dp_only=dp),
        "blocks": SH.tree_shardings(defs["blocks"], mesh, placement=blocks_placement),
    }
    if "head" in defs:
        p_defs["head"] = defs["head"]
        p_shard["head"] = SH.tree_shardings(defs["head"], mesh, placement=head_pchunk, dp_only=dp)
    if "encoder" in defs:
        p_defs["encoder"] = defs["encoder"]
        p_shard["encoder"] = SH.tree_shardings(defs["encoder"], mesh, placement=embed_pchunk, dp_only=dp)
    gather = SH.tree_gather_shardings(defs["blocks"], mesh,
                                      persistent=blocks_placement == "persist")

    def _fs(subtree_defs, placement, force=False):
        if placement != "host" and not force:
            return None
        return jax.tree.map(lambda d: SH.gather_sharding(d, mesh), subtree_defs,
                            is_leaf=lambda x: isinstance(x, ParamDef))

    fetch_specs = {
        "embed": _fs(defs["embed"], embed_chunk),
        "final_norm": _fs(defs["final_norm"], head_chunk),
    }
    if "head" in defs:
        fetch_specs["head"] = _fs(defs["head"], head_chunk, force=head_chunk != "persist")

    def fetch(params):
        out = dict(params)
        for key in ("embed", "final_norm", "head"):
            spec = fetch_specs.get(key)
            if spec is not None and key in out:
                out[key] = jax.tree.map(jax.device_put, out[key], spec)
        return out

    return p_defs, p_shard, gather, fetch


def _serve_cache_layout(cfg: ModelConfig, plan: MemoryPlan, mesh,
                        shape: ShapeConfig, paging):
    """Shared decode/prefill cache layout for a serve plan.

    Returns ``(cache_sds, cache_shard, kv_io, host_pin, tok_batch_ax)``:
    the sharded cache ShapeDtypeStructs, their sharding tree, the PagedKV
    hook (None for resident layouts), the cold-leaf re-pin tree (paged
    layouts re-emit cold leaves in device memory out of the repeat scan),
    and the batch axis tokens shard over."""
    from repro.compat import host_memory_kind

    bsz = shape.global_batch
    if paging is None:
        cache_spec_tree = KV.cache_specs(cfg, bsz, shape.seq_len)
    else:
        from repro.serve.paging import paged_cache_specs

        cache_spec_tree = paged_cache_specs(cfg, bsz, shape.seq_len, paging)
    ba = SH.batch_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    host_kind = host_memory_kind(mesh)

    def fits(dim: int, axes) -> bool:
        if axes is None:
            return False
        names = (axes,) if isinstance(axes, str) else axes
        n = 1
        for a in names:
            n *= sizes[a]
        return dim % n == 0 and dim >= n

    def cache_sharding(name: str, s: jax.ShapeDtypeStruct) -> NamedSharding:
        """Attention caches (R,B,S,kv,hd): batch over ZeRO axes when divisible;
        the sequence dim takes TP (and absorbs the ZeRO axes too for
        single-sequence long-context decode, where batch cannot shard).
        Paged leaves reuse the same geometry — hot rings and cold pages are
        slot-axis slices of the resident layout — with cold pinned to the
        platform's host memory kind."""
        shp = s.shape
        batch_ax = ba if fits(shp[1], ba) else None
        if name in ("k", "v", "xk", "xv", "k_hot", "v_hot", "k_cold", "v_cold"):
            seq_ax = tp if batch_ax is not None else tuple(
                a for a in ((ba or ()) + ((tp,) if tp else ())) if a
            ) or None
            if not fits(shp[2], seq_ax):
                seq_ax = tp if fits(shp[2], tp) else None
            spec = P(None, batch_ax, seq_ax, None, None)
            if name in ("k_cold", "v_cold") and host_kind is not None:
                return NamedSharding(mesh, spec, memory_kind=host_kind)
            return NamedSharding(mesh, spec)
        if name == "conv":  # (R, B, K, conv_dim)
            ch = tp if fits(shp[3], tp) else None
            return NamedSharding(mesh, P(None, batch_ax, None, ch))
        if name == "ssm":  # (R, B, H, P, N)
            h = tp if fits(shp[2], tp) else None
            return NamedSharding(mesh, P(None, batch_ax, h, None, None))
        raise KeyError(name)

    cache_shard = {
        pos: {name: cache_sharding(name, s) for name, s in entry.items()}
        for pos, entry in cache_spec_tree.items()
    }
    tok_batch_ax = ba if fits(bsz, ba) else None
    cache_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_spec_tree, cache_shard,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    kv_io = None
    host_pin = None
    if paging is not None:
        from repro.serve.paging import PagedKV

        # one fetched page, per repeat: (B, P, n_kv, hd), batch-sharded,
        # device memory — the h2d target of the cold-page device_put
        page_batch_ax = ba if fits(bsz, ba) else None
        fetch_sharding = NamedSharding(mesh, P(page_batch_ax, None, None, None))
        kv_io = PagedKV(paging, fetch_sharding=fetch_sharding)
        # the repeat scan re-emits cold leaves in device memory; pin them back
        host_pin = {
            pos: {name: sh for name, sh in entry.items()
                  if name in ("k_cold", "v_cold")}
            for pos, entry in cache_shard.items()
        }
    return cache_sds, cache_shard, kv_io, host_pin, tok_batch_ax


def _repin_cold(new_cache: dict, host_pin) -> dict:
    if host_pin is None:
        return new_cache
    return {
        pos: {
            name: (jax.device_put(leaf, host_pin[pos][name])
                   if name in host_pin[pos] else leaf)
            for name, leaf in entry.items()
        }
        for pos, entry in new_cache.items()
    }


def _resolve_paging(cfg: ModelConfig, plan: MemoryPlan, shape: ShapeConfig, paging):
    """Derive the PagingSpec a serve plan encodes when none is passed."""
    if paging is None and plan.cold_kv_pages > 0:
        from repro.core.serve_plan import paging_from_plan

        paging = paging_from_plan(cfg, shape, plan)
    return paging


def build_decode_step(cfg: ModelConfig, plan: MemoryPlan, mesh, shape: ShapeConfig,
                      *, paging=None, per_slot_pos: bool = False) -> StepArtifacts:
    """Decode step for a serve plan.

    ``paging`` (a ``serve.paging.PagingSpec``) switches the attention caches
    to the paged layout: hot rings stay in HBM, the canonical cold pages live
    in host memory (``compat.host_memory_kind``), and the step reconstructs
    each layer's cache page-wise inside the repeat scan through the
    ``PagedKV`` kv_io hook — the serving twin of ``Run.lazy_gather``. When
    ``plan.cold_kv_pages > 0`` and no spec is passed, one is derived via
    ``serve_plan.paging_from_plan``. ``per_slot_pos`` widens the ``pos``
    input to (B,) so every batch slot decodes at its own position
    (continuous batching), and adds an optional ``active`` (B,) bool batch
    input masking cache writes of non-participating slots (the engine passes
    it when some slots are mid-chunked-prefill)."""
    paging = _resolve_paging(cfg, plan, shape, paging)
    p_defs, p_shard, gather, fetch = build_serve_params(cfg, plan, mesh)
    sharder = SH.make_activation_sharder(mesh, plan)
    bsz = shape.global_batch

    cache_sds, cache_shard, kv_io, host_pin, tok_batch_ax = _serve_cache_layout(
        cfg, plan, mesh, shape, paging)

    state_specs = {
        "params": SH.tree_specs(p_defs, p_shard),
        "cache": cache_sds,
    }
    pos_spec = (jax.ShapeDtypeStruct((bsz,), jnp.int32) if per_slot_pos
                else jax.ShapeDtypeStruct((), jnp.int32))
    batch_specs = {
        "tokens": jax.ShapeDtypeStruct(
            (bsz, 1), jnp.int32, sharding=NamedSharding(mesh, P(tok_batch_ax, None))
        ),
        "pos": pos_spec,
    }
    if per_slot_pos:
        batch_specs["active"] = jax.ShapeDtypeStruct((bsz,), jnp.bool_)

    def step_fn(state, batch):
        M.set_activation_sharder(sharder)
        fparams = fetch(state["params"])
        logits, new_cache = KV.decode_step(
            fparams, state["cache"], batch["tokens"], batch["pos"], cfg,
            gather_specs=gather, kv_io=kv_io, active=batch.get("active"),
        )
        new_cache = _repin_cold(new_cache, host_pin)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return {"params": state["params"], "cache": new_cache}, next_tok

    return StepArtifacts(
        fn=step_fn,
        state_specs=state_specs,
        batch_specs=batch_specs,
        state_shardings={"params": p_shard, "cache": cache_shard},
        batch_shardings=None,
        plan=plan,
        runs=plan_runs(plan, M.num_repeats(cfg)),
    )


def build_prefill_step(cfg: ModelConfig, plan: MemoryPlan, mesh, shape: ShapeConfig,
                       *, chunk: int | None = None, paging=None) -> StepArtifacts:
    """Prefill for a serve plan, in one of two forms.

    ``chunk=None`` (legacy): a stateless full-sequence parallel forward
    returning last-position logits — the shape/fidelity dryrun path, which
    never touches a decode cache.

    ``chunk=C``: the cache-ingesting chunked prefill the serving engine
    admits requests through (serve/prefill.py). State and shardings match
    ``build_decode_step`` exactly (params + decode cache, paged or resident),
    so one state dict threads through both programs; the batch is a (B, C)
    token block with per-slot start positions and per-slot token counts.
    Feeding the same tokens through this step and through token-by-token
    decode replay produces bitwise-identical caches and logits (the per-token
    ops are the same; tests/test_serve_prefill.py asserts diff == 0.0).
    """
    if chunk is not None:
        return _build_chunked_prefill_step(cfg, plan, mesh, shape,
                                           chunk=chunk, paging=paging)
    p_defs, p_shard, gather, fetch = build_serve_params(cfg, plan, mesh)
    sharder = SH.make_activation_sharder(mesh, plan)
    gb, sl = shape.global_batch, shape.seq_len
    bsh = SH.batch_sharding(mesh, 2)
    batch_specs: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((gb, sl), jnp.int32, sharding=bsh),
    }
    if cfg.kind == "encdec":
        batch_specs["frames"] = jax.ShapeDtypeStruct(
            (gb, sl, cfg.d_model), jnp.dtype(cfg.dtype), sharding=SH.batch_sharding(mesh, 3)
        )
    if cfg.frontend == "vision_patches":
        batch_specs["patches"] = jax.ShapeDtypeStruct(
            (gb, min(1024, sl), cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=SH.batch_sharding(mesh, 3),
        )

    def step_fn(params, batch):
        M.set_activation_sharder(sharder)
        params = fetch(params)
        runs = [
            M.Run(params=params["blocks"], n_repeats=M.num_repeats(cfg),
                  act_policy="none", buffered=True,
                  persistent=plan.chunk_placement(1) == "persist", gather_specs=gather)
        ]
        h, _ = M.forward(params, batch, cfg, runs=runs)
        from repro.models.layers import apply_norm

        h = apply_norm(params["final_norm"], h[:, -1:], cfg.norm)
        w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
        return (h @ w)[:, 0]  # (B, V) next-token logits

    return StepArtifacts(
        fn=step_fn,
        state_specs=SH.tree_specs(p_defs, p_shard),
        batch_specs=batch_specs,
        state_shardings=p_shard,
        batch_shardings=None,
        plan=plan,
        runs=plan_runs(plan, M.num_repeats(cfg)),
    )


def _build_chunked_prefill_step(cfg: ModelConfig, plan: MemoryPlan, mesh,
                                shape: ShapeConfig, *, chunk: int,
                                paging=None) -> StepArtifacts:
    from repro.serve.prefill import prefill_chunk

    paging = _resolve_paging(cfg, plan, shape, paging)
    p_defs, p_shard, gather, fetch = build_serve_params(cfg, plan, mesh)
    sharder = SH.make_activation_sharder(mesh, plan)
    bsz = shape.global_batch

    cache_sds, cache_shard, kv_io, host_pin, tok_batch_ax = _serve_cache_layout(
        cfg, plan, mesh, shape, paging)

    state_specs = {
        "params": SH.tree_specs(p_defs, p_shard),
        "cache": cache_sds,
    }
    batch_specs = {
        "tokens": jax.ShapeDtypeStruct(
            (bsz, chunk), jnp.int32,
            sharding=NamedSharding(mesh, P(tok_batch_ax, None))),
        "pos": jax.ShapeDtypeStruct((bsz,), jnp.int32),
        "n_tok": jax.ShapeDtypeStruct((bsz,), jnp.int32),
    }

    def step_fn(state, batch):
        M.set_activation_sharder(sharder)
        fparams = fetch(state["params"])
        last, new_cache = prefill_chunk(
            fparams, state["cache"], batch["tokens"], batch["pos"],
            batch["n_tok"], cfg, gather_specs=gather, kv_io=kv_io,
        )
        new_cache = _repin_cold(new_cache, host_pin)
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return {"params": state["params"], "cache": new_cache}, next_tok

    return StepArtifacts(
        fn=step_fn,
        state_specs=state_specs,
        batch_specs=batch_specs,
        state_shardings={"params": p_shard, "cache": cache_shard},
        batch_shardings=None,
        plan=plan,
        runs=plan_runs(plan, M.num_repeats(cfg)),
    )
