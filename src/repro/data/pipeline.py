"""Data pipeline: deterministic synthetic token streams with sharded device
placement, background host prefetch, and checkpointable iterator state.

Synthetic data is generated per (seed, step) so the stream is stateless-
resumable: restoring a checkpoint at step N reproduces exactly the batches
the crashed run would have seen (a fault-tolerance requirement — see
ckpt/checkpoint.py). The same interface is what a real corpus-backed loader
would implement (``state()`` / ``from_state``).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int


class SyntheticTokenPipeline:
    """Markov-ish synthetic LM batches (not uniform noise: loss curves need
    learnable structure for the examples/tests to show convergence)."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        *,
        seed: int = 0,
        start_step: int = 0,
        shardings=None,
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.step = start_step
        self.shardings = shardings
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # --- synthesis ----------------------------------------------------------
    def _make_host_batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        b, s, v = self.shape.global_batch, self.shape.seq_len, self.cfg.vocab_size
        # structured stream: tokens follow t_{i+1} = (a * t_i + b) % v with
        # per-sequence (a, b) — learnable transition structure
        a = rng.integers(1, 17, size=(b, 1))
        c = rng.integers(0, v, size=(b, 1))
        t0 = rng.integers(0, v, size=(b, 1))
        idx = np.arange(s)[None, :]
        tokens = ((a ** (idx % 5 + 1)) * t0 + c * idx) % v
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.kind == "encdec":
            out["frames"] = rng.standard_normal((b, s, self.cfg.d_model)).astype(np.float32)
        if self.cfg.frontend == "vision_patches":
            n_patch = min(1024, s)
            out["patches"] = rng.standard_normal((b, n_patch, self.cfg.d_model)).astype(np.float32)
        return out

    def _device_put(self, host: dict) -> dict:
        dt = jnp.dtype(self.cfg.dtype)
        out = {}
        for k, v in host.items():
            arr = v if v.dtype == np.int32 else v.astype(dt)
            sh = self.shardings.get(k) if self.shardings else None
            out[k] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
        return out

    # --- iteration ----------------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            self._start_prefetch()
        batch = self._queue.get()
        if isinstance(batch, Exception):
            raise batch
        return batch

    def _start_prefetch(self):
        def worker():
            step = self.step
            while not self._stop.is_set():
                try:
                    host = self._make_host_batch(step)
                    self._queue.put(self._device_put(host))
                    step += 1
                except Exception as e:  # surface in consumer
                    self._queue.put(e)
                    return

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_sync(self) -> dict:
        """Prefetch-free single batch (used by tests and the dry-run)."""
        batch = self._device_put(self._make_host_batch(self.step))
        self.step += 1
        return batch

    def stop(self):
        self._stop.set()

    # --- checkpointable state -------------------------------------------------
    def state(self) -> PipelineState:
        return PipelineState(seed=self.seed, step=self.step)

    @classmethod
    def from_state(cls, cfg, shape, state: PipelineState, **kw):
        return cls(cfg, shape, seed=state.seed, start_step=state.step, **kw)
