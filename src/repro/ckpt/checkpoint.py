"""Fault-tolerant sharded checkpointing.

Design targets (1000+ node deployments):
  * step-granular sharded saves: each host writes only the shards it owns
    (here: the addressable shards of every array), as ``.npy`` per leaf shard;
  * atomic publish: writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after a manifest fsync — a crashed save can never be
    mistaken for a valid checkpoint;
  * async: the device->host transfer is synchronous (cheap), the disk write
    happens on a background thread so training continues;
  * elastic restore: arrays are saved with their *global* logical shape and
    loaded back through ``jax.make_array_from_callback`` against the *new*
    sharding — a checkpoint taken on 256 chips restores onto 512 (or onto a
    different MemoryPlan's run split, since the layout metadata stores the
    canonical stacked-parameter view);
  * data-pipeline state and the MemoryPlan are stored in the manifest.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


def _gather_to_host(arr: jax.Array) -> np.ndarray:
    """Assemble the full logical array from addressable shards (single-host
    here; on multi-host each host writes only its shards)."""
    if hasattr(arr, "addressable_shards"):
        out = np.zeros(arr.shape, dtype=arr.dtype)
        for shard in arr.addressable_shards:
            out[shard.index] = np.asarray(shard.data)
        return out
    return np.asarray(arr)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # --- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None, *, sync: bool = False):
        """Snapshot to host memory now; write to disk in the background."""
        host_leaves = [(k, _gather_to_host(v)) for k, v in _flatten_with_paths(state)]
        # bf16 has no portable npy representation: store as uint16 views
        dtypes = {}
        packed = []
        for k, arr in host_leaves:
            if arr.dtype.name == "bfloat16":
                dtypes[k] = "bfloat16"
                arr = arr.view(np.uint16)
            packed.append((k, arr))
        host_leaves = packed
        manifest = {
            "step": step,
            "leaves": [k for k, _ in host_leaves],
            "dtypes": dtypes,
            "extra": extra or {},
        }
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time

        def write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp")
            final = os.path.join(self.directory, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for key, arr in host_leaves:
                np.save(os.path.join(tmp, key.replace("/", "__") + ".npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        self._thread = threading.Thread(target=write, daemon=False)
        self._thread.start()
        if sync:
            self._thread.join()

    def wait(self):
        if self._thread is not None:
            self._thread.join()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # --- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_specs: Any) -> tuple[Any, dict]:
        """Load into ``target_specs`` (ShapeDtypeStructs with shardings) —
        elastic: the target mesh/sharding may differ from the saving run's."""
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        saved_dtypes = manifest.get("dtypes", {})

        def load_leaf(keyed):
            key, spec = keyed
            fname = os.path.join(path, key.replace("/", "__") + ".npy")
            host = np.load(fname)
            if saved_dtypes.get(key) == "bfloat16":
                import ml_dtypes

                host = host.view(ml_dtypes.bfloat16)
            if tuple(host.shape) != tuple(spec.shape):
                raise ValueError(f"shape mismatch for {key}: {host.shape} vs {spec.shape}")
            sharding = getattr(spec, "sharding", None)
            if sharding is None:
                return jax.numpy.asarray(host, dtype=spec.dtype)
            if host.dtype != spec.dtype and str(spec.dtype) != str(host.dtype):
                host = np.asarray(jax.numpy.asarray(host).astype(spec.dtype))
            return jax.make_array_from_callback(
                tuple(spec.shape), sharding, lambda idx: host[idx]
            )

        flat_specs = _flatten_with_paths(target_specs)
        restored_flat = [load_leaf(k) for k in flat_specs]
        treedef = jax.tree.structure(target_specs)
        return jax.tree.unflatten(treedef, restored_flat), manifest["extra"]

    def restore_latest(self, target_specs: Any):
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, target_specs)
        return step, state, extra
