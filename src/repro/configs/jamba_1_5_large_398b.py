"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import JAMBA_1_5_LARGE_398B as CONFIG

__all__ = ["CONFIG"]
