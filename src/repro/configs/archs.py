"""The 10 assigned architectures (public configs, see brackets for source).

Each is exposed as a module-level ``CONFIG`` via per-arch shim modules and
collected in ``ARCHS`` for ``--arch <id>`` selection.
"""
from __future__ import annotations

from repro.configs.base import Mamba2Config, ModelConfig, MoeConfig

# [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave (attention every 8th
# layer), MoE 16e top-2 applied every other layer.
JAMBA_1_5_LARGE_398B = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mlp="swiglu",
    mixer_pattern=(
        "mamba2", "mamba2", "mamba2", "attention",
        "mamba2", "mamba2", "mamba2", "mamba2",
    ),
    moe=MoeConfig(num_experts=16, top_k=2, every=2),
    mamba2=Mamba2Config(d_state=128, head_dim=64, expand=2),
)

# [hf:stabilityai/stablelm-2-1_6b; unverified] — MHA (kv == heads).
STABLELM_3B = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    mlp="swiglu",
    norm="layernorm",
)

# [arXiv:2407.21783; unverified] — GQA kv=8, 128k vocab.
LLAMA3_405B = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    mlp="swiglu",
    rope_theta=500_000.0,
)

# [arXiv:2402.19173; hf] — GQA kv=4, RoPE, non-gated GELU MLP.
STARCODER2_15B = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",
    norm="layernorm",
)

# [arXiv:2402.16819; unverified] — squared-ReLU MLP, 256k vocab.
NEMOTRON_4_340B = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp="relu2",
    norm="layernorm",
)

# [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 60 routed top-4 + 4 shared experts.
QWEN2_MOE_A2_7B = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    mlp="swiglu",
    moe=MoeConfig(num_experts=60, top_k=4, num_shared_experts=4, d_expert=1408),
)

# [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window attention.
MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    mlp="swiglu",
    sliding_window=4096,
    moe=MoeConfig(num_experts=8, top_k=2),
)

# [arXiv:2405.21060; unverified] — pure SSD, attention-free, no MLP stack.
MAMBA2_130M = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    mixer_pattern=("mamba2",),
    mamba2=Mamba2Config(d_state=128, head_dim=64, expand=2),
)

# [arXiv:2308.11596; hf] — enc-dec; audio frontend stubbed (frame embeddings).
SEAMLESS_M4T_LARGE_V2 = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    kind="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    mlp="gelu",
    norm="layernorm",
    frontend="audio_frames",
)

# [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — LM backbone only;
# anyres vision tiling stubbed (patch embeddings).
LLAVA_NEXT_34B = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp="swiglu",
    frontend="vision_patches",
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        JAMBA_1_5_LARGE_398B,
        STABLELM_3B,
        LLAMA3_405B,
        STARCODER2_15B,
        NEMOTRON_4_340B,
        QWEN2_MOE_A2_7B,
        MIXTRAL_8X22B,
        MAMBA2_130M,
        SEAMLESS_M4T_LARGE_V2,
        LLAVA_NEXT_34B,
    )
}
