"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import LLAVA_NEXT_34B as CONFIG

__all__ = ["CONFIG"]
