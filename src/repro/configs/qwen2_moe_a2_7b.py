"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import QWEN2_MOE_A2_7B as CONFIG

__all__ = ["CONFIG"]
