"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import NEMOTRON_4_340B as CONFIG

__all__ = ["CONFIG"]
