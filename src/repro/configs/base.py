"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the four shape
suites are ``ShapeConfig``s. Configs are plain frozen dataclasses so they can be
hashed into jit cache keys and serialized into checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

MixerKind = Literal["attention", "mamba2"]
MlpKind = Literal["swiglu", "gelu", "relu2", "geglu"]
ModelKind = Literal["decoder", "encdec"]
Frontend = Literal["none", "audio_frames", "vision_patches"]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    """Mixture-of-experts settings for MoE/hybrid layers."""

    num_experts: int
    top_k: int
    # Experts that every token passes through (Qwen-MoE style), 0 for pure MoE.
    num_shared_experts: int = 0
    # d_ff of each expert (may differ from the dense d_ff).
    d_expert: int = 0
    # Apply MoE every `every` layers (1 = all layers, 2 = alternating, ...).
    every: int = 1
    # Router jitter / load-balance loss weight.
    aux_loss_weight: float = 0.01
    # Expert capacity = ceil(top_k * tokens / num_experts * capacity_factor).
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    """SSD (state-space duality) mixer settings [arXiv:2405.21060]."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256  # SSD block size along sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    kind: ModelKind = "decoder"
    head_dim: int = 0  # 0 -> d_model // num_heads
    mlp: MlpKind = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10_000.0
    # Sliding-window attention size; 0 = full attention.
    sliding_window: int = 0
    # Per-layer mixer pattern, tiled over layers (e.g. Jamba 1 attn : 7 mamba).
    mixer_pattern: Sequence[MixerKind] = ("attention",)
    moe: MoeConfig | None = None
    mamba2: Mamba2Config | None = None
    # Encoder config for encdec models (decoder uses the top-level fields).
    encoder_layers: int = 0
    # Modality frontend stub: the model consumes precomputed embeddings.
    frontend: Frontend = "none"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def mixer_at(self, layer: int) -> MixerKind:
        return self.mixer_pattern[layer % len(self.mixer_pattern)]

    def moe_at(self, layer: int) -> bool:
        return self.moe is not None and (layer % self.moe.every) == (self.moe.every - 1)

    @property
    def attention_free(self) -> bool:
        return all(m != "attention" for m in self.mixer_pattern)

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM/hybrid state or SWA window)."""
        return self.attention_free or self.sliding_window > 0 or any(
            m == "mamba2" for m in self.mixer_pattern
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), exact for our zoo."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = v * d  # token embedding
        if not self.tie_embeddings:
            total += v * d  # lm head

        def attn_params() -> int:
            return d * n_q + 2 * d * n_kv + n_q * d

        def mamba_params() -> int:
            mc = self.mamba2
            d_in = mc.expand * d
            n_h = d_in // mc.head_dim
            # in_proj: z, x, B, C, dt
            zxbcdt = d * (2 * d_in + 2 * mc.d_state + n_h)
            conv = mc.d_conv * (d_in + 2 * mc.d_state)
            out = d_in * d
            return zxbcdt + conv + out + 2 * n_h  # + A_log, D

        def dense_mlp() -> int:
            mults = 3 if self.mlp in ("swiglu", "geglu") else 2
            return mults * d * ff

        def moe_mlp() -> int:
            mc = self.moe
            de = mc.d_expert or ff
            per = 3 * d * de if self.mlp in ("swiglu", "geglu") else 2 * d * de
            return mc.num_experts * per + mc.num_shared_experts * per + d * mc.num_experts

        def block(layer: int) -> int:
            mixer = attn_params() if self.mixer_at(layer) == "attention" else mamba_params()
            mlp = moe_mlp() if self.moe_at(layer) else (dense_mlp() if ff else 0)
            return mixer + mlp + 2 * d  # two norms

        total += sum(block(l) for l in range(self.num_layers))
        if self.kind == "encdec":
            # encoder blocks (dense attention + mlp) + decoder cross-attn
            enc_block = attn_params() + dense_mlp() + 2 * d
            total += self.encoder_layers * enc_block
            total += self.num_layers * (attn_params() + d)  # cross attn + norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        mc = self.moe
        de = mc.d_expert or self.d_ff
        per = (3 if self.mlp in ("swiglu", "geglu") else 2) * self.d_model * de
        inactive = (mc.num_experts - mc.top_k) * per
        n_moe_layers = sum(1 for l in range(self.num_layers) if self.moe_at(l))
        return self.param_count() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]

    @property
    def is_training(self) -> bool:
        return self.mode == "train"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(config: ModelConfig) -> tuple[ShapeConfig, ...]:
    """The shape cells defined for this architecture (assignment rules)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if config.subquadratic:
        shapes.append(LONG_500K)
    return tuple(shapes)


def reduced(config: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes: dict = dict(
        num_layers=min(config.num_layers, 2 * len(config.mixer_pattern))
        if len(config.mixer_pattern) > 1
        else 2,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(config.num_kv_heads, 4) if config.num_kv_heads else 4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        encoder_layers=2 if config.kind == "encdec" else 0,
        sliding_window=min(config.sliding_window, 64) if config.sliding_window else 0,
    )
    if config.moe is not None:
        changes["moe"] = dataclasses.replace(
            config.moe,
            num_experts=4,
            top_k=min(config.moe.top_k, 2),
            d_expert=128 if config.moe.d_expert else 0,
            capacity_factor=8.0,  # drop-free so decode == prefill in tests
        )
    if config.mamba2 is not None:
        changes["mamba2"] = dataclasses.replace(
            config.mamba2, d_state=16, head_dim=32, chunk_size=32
        )
    # keep hybrid patterns: at least one full pattern repetition
    if len(config.mixer_pattern) > 1:
        changes["num_layers"] = len(config.mixer_pattern)
    changes.update(overrides)
    return dataclasses.replace(config, **changes)
