"""Paper Table 1 model configurations (GPT-2 / OPT / Mistral / LLaMA sizes).

Used by the benchmark harness to reproduce the paper's tables; sequence length
in the paper is fixed at 1024.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _gpt2(name: str, hidden: int, blocks: int, heads: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=blocks,
        d_model=hidden,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=4 * hidden,
        vocab_size=50257,
        mlp="gelu",
        norm="layernorm",
        tie_embeddings=True,
    )


def _llama(name: str, hidden: int, blocks: int, heads: int, ff: int) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=blocks,
        d_model=hidden,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=ff,
        vocab_size=32000,
        mlp="swiglu",
    )


# Table 1 rows (parameter sizes are the paper's labels).
GPT2_1B = _gpt2("gpt2-1b", 2048, 18, 16)  # row A/B/C of Table 4
GPT2_10B = _gpt2("gpt2-10b", 4096, 48, 32)
GPT2_15B = _gpt2("gpt2-15b", 8192, 18, 64)
GPT2_20B = _gpt2("gpt2-20b", 8192, 24, 64)
GPT2_30B = _gpt2("gpt2-30b", 8192, 36, 64)
GPT2_40B = _gpt2("gpt2-40b", 8192, 50, 64)
MISTRAL_7B = ModelConfig(
    name="mistral-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    mlp="swiglu",
    sliding_window=4096,
)
OPT_13B = ModelConfig(
    name="opt-13b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=20480,
    vocab_size=50272,
    mlp="relu2",  # OPT uses ReLU; relu2 is our closest kind — see DESIGN.md
    norm="layernorm",
)
OPT_30B = ModelConfig(
    name="opt-30b",
    family="dense",
    num_layers=48,
    d_model=7168,
    num_heads=56,
    num_kv_heads=56,
    d_ff=28672,
    vocab_size=50272,
    mlp="relu2",
    norm="layernorm",
)
LLAMA_13B = _llama("llama-13b", 5120, 40, 40, 13824)
LLAMA_34B = _llama("llama-34b", 8192, 48, 64, 22016)

PAPER_MODELS = {
    m.name: m
    for m in (
        GPT2_1B, GPT2_10B, GPT2_15B, GPT2_20B, GPT2_30B, GPT2_40B,
        MISTRAL_7B, OPT_13B, OPT_30B, LLAMA_13B, LLAMA_34B,
    )
}

# The paper's controlled-comparison shape: seq 1024, batch swept per bench.
def paper_shape(batch: int) -> ShapeConfig:
    return ShapeConfig(f"paper_b{batch}", 1024, batch, "train")
