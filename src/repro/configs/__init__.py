"""Config registry: ``get_config("llama3-405b")`` / ``--arch`` ids."""
from __future__ import annotations

from repro.configs.archs import ARCHS
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    Mamba2Config,
    ModelConfig,
    MoeConfig,
    ShapeConfig,
    reduced,
    shapes_for,
)
from repro.configs.paper_models import PAPER_MODELS, paper_shape

REGISTRY: dict[str, ModelConfig] = {**ARCHS, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")
