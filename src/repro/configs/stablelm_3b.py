"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import STABLELM_3B as CONFIG

__all__ = ["CONFIG"]
