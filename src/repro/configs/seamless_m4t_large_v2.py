"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import SEAMLESS_M4T_LARGE_V2 as CONFIG

__all__ = ["CONFIG"]
