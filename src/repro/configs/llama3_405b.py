"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import LLAMA3_405B as CONFIG

__all__ = ["CONFIG"]
