"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import STARCODER2_15B as CONFIG

__all__ = ["CONFIG"]
