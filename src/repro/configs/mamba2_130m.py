"""Assigned architecture config (see archs.py for the exact values)."""
from repro.configs.archs import MAMBA2_130M as CONFIG

__all__ = ["CONFIG"]
