"""Continuous-batching scheduler: requests -> batch slots -> pages.

Host-side bookkeeping only (plain Python, no jax): the decode engine asks the
scheduler each step which token/position every batch slot should decode, and
reports the sampled tokens back. The scheduler

  * admits queued requests into free slots (prompt tokens are then replayed
    through the decode step — teacher-forced prefill, per-slot positions);
  * allocates cache pages lazily as a slot's sequence crosses page
    boundaries, against a bounded ``PagePool`` (the page-table analogue of
    vLLM's block allocator: our physical storage is dense slot-major, the
    pool is the *capacity* ledger the admission policy respects);
  * evicts the youngest running slot back to the queue when the pool runs
    dry (its pages are freed; the request restarts from its prompt later);
  * finishes slots that produced ``max_new_tokens`` (or hit the cache
    length) and frees their pages.

Invariants (property-tested in tests/test_serve_paging.py):
  free pages + pages held by live slots == pool size, with no page held
  twice; every admitted request either finishes exactly once or returns to
  the queue; slot occupancy and page ownership never leak across
  admit/evict/finish cycles.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

from repro.obs.metrics import NULL_REGISTRY


@dataclasses.dataclass
class Request:
    """Canonical submission form: ``prompt_tokens`` + ``max_new_tokens``.

    ``prompt`` remains as a read alias for the pre-redesign field name
    (positional construction is unchanged).
    """

    rid: int
    prompt_tokens: list[int]  # token ids (at least one)
    max_new_tokens: int

    def __post_init__(self):
        assert len(self.prompt_tokens) >= 1 and self.max_new_tokens >= 1

    @property
    def prompt(self) -> list[int]:
        return self.prompt_tokens


class PagePool:
    """Bounded free-list of physical cache pages."""

    def __init__(self, n_pages: int):
        assert n_pages >= 1
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._owner: dict[int, int] = {}  # page id -> slot index

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, slot: int, n: int = 1) -> list[int] | None:
        """n pages for ``slot``, or None (and no change) if unavailable."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = slot
        return pages

    def free_slot(self, slot: int) -> int:
        """Release every page owned by ``slot``; returns the count."""
        pages = [p for p, s in self._owner.items() if s == slot]
        for p in pages:
            del self._owner[p]
            self._free.append(p)
        return len(pages)

    def held_by(self, slot: int) -> int:
        return sum(1 for s in self._owner.values() if s == slot)


@dataclasses.dataclass
class SlotState:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    length: int = 0  # tokens written to the slot's cache so far
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def in_prefill(self) -> bool:
        return self.length < len(self.prompt)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ContinuousScheduler:
    """Admit/evict/finish requests over ``n_slots`` decode batch slots.

    ``page_size``/``cache_len`` define each slot's page demand: a slot at
    sequence length L holds ceil(L / page_size) pages, capped at the ring
    page count. ``allow_wrap`` (ring caches: sliding-window attention, or
    attention-free state models) lets a slot decode *past* ``cache_len`` —
    the cache ring reuses its slots, so a wrapped slot allocates nothing
    new; without it (full attention) a slot is force-finished when its
    cache slots run out, recorded in ``truncated``.
    """

    def __init__(self, n_slots: int, pool: PagePool, page_size: int,
                 cache_len: int, allow_wrap: bool = False, registry=None):
        assert n_slots >= 1 and page_size >= 1
        self.n_slots = n_slots
        self.pool = pool
        self.page_size = page_size
        self.cache_len = cache_len
        self.allow_wrap = allow_wrap
        self.truncated: set[int] = set()  # rids finished by cache exhaustion
        self.max_pages_per_slot = -(-cache_len // page_size)
        self.queue: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * n_slots
        self.finished: dict[int, list[int]] = {}
        self.rejected: dict[int, list[int]] = {}  # page demand > pool capacity
        self.evictions = 0
        # telemetry (obs.MetricsRegistry or the no-op default): request
        # lifecycle counters + PagePool occupancy gauges
        reg = registry if registry is not None else NULL_REGISTRY
        self._c_admitted = reg.counter("serve.admitted")
        self._c_evictions = reg.counter("serve.evictions")
        self._c_finished = reg.counter("serve.finished")
        self._c_rejected = reg.counter("serve.rejected")
        self._c_truncated = reg.counter("serve.truncated")
        self._g_pool_free = reg.gauge("serve.pagepool_free")
        self._g_pool_occ = reg.gauge("serve.pagepool_occupancy")

    def _note_pool(self) -> None:
        free = self.pool.n_free
        self._g_pool_free.set(free)
        self._g_pool_occ.set(1.0 - free / self.pool.n_pages)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, requests: Iterable[Request]) -> None:
        self.queue.extend(requests)

    def _pages_needed(self, length: int) -> int:
        return min(-(-max(length, 1) // self.page_size), self.max_pages_per_slot)

    def admit(self) -> list[int]:
        """Fill free slots from the queue (first page must be allocatable).
        Returns the slot indices admitted this call (engine resets them)."""
        admitted = []
        for b in range(self.n_slots):
            if self.slots[b] is not None or not self.queue:
                continue
            if self.pool.alloc(b, 1) is None:
                break  # no first page -> nothing else will fit either
            req = self.queue.popleft()
            self.slots[b] = SlotState(req.rid, list(req.prompt), req.max_new_tokens)
            admitted.append(b)
        if admitted:
            self._c_admitted.inc(len(admitted))
            self._note_pool()
        return admitted

    def _evict_youngest(self) -> bool:
        """Free the shortest-running slot back to the queue (least replay
        work lost); returns False when nothing is evictable."""
        live = [(b, s) for b, s in enumerate(self.slots) if s is not None]
        if len(live) <= 1:
            return False  # never evict the last runner: no progress otherwise
        b, s = min(live, key=lambda bs: bs[1].length)
        self.pool.free_slot(b)
        self.slots[b] = None
        self.queue.appendleft(Request(s.rid, s.prompt, s.max_new_tokens))
        self.evictions += 1
        self._c_evictions.inc()
        self._note_pool()
        return True

    # -- per-step interface ---------------------------------------------------
    def step_inputs(self, replay_prefill: bool = True
                    ) -> tuple[list[int], list[int], list[bool]]:
        """(token, position, active) per slot for the next decode step.

        Decode slots feed their last sampled token. Prefill slots replay
        their prompt token at the current position when ``replay_prefill``
        (the legacy teacher-forced admission path); with it False (chunked
        prefill owns prompt ingestion) they sit the decode tick out as
        inactive. Inactive slots decode token 0 at position 0 — their output
        is discarded, and the ``active`` mask suppresses their cache writes
        (models.kvcache.write_slot), so mid-prefill slots keep their rows.
        """
        toks, poss, active = [], [], []
        for s in self.slots:
            if s is None or (s.in_prefill and not replay_prefill):
                toks.append(0)
                poss.append(0)
                active.append(False)
                continue
            if s.in_prefill:
                toks.append(s.prompt[s.length])
            else:
                toks.append(s.generated[-1])
            poss.append(s.length)
            active.append(True)
        return toks, poss, active

    def ensure_pages(self, b: int, target_len: int) -> bool:
        """Grow slot ``b``'s page hold to cover ``target_len``, evicting
        youngest runners (never the last) and rejecting outright when the
        demand exceeds the whole pool. Returns True iff the slot survived
        (it may itself be the youngest and get evicted)."""
        s = self.slots[b]
        need = self._pages_needed(target_len)
        while self.slots[b] is not None and self.pool.held_by(b) < need:
            if self.pool.alloc(b, 1) is not None:
                continue
            if not self._evict_youngest():
                # b is the last runner and owns every page: its demand
                # exceeds the pool outright — reject, don't livelock
                self.rejected[s.rid] = list(s.generated)
                self._c_rejected.inc()
                self.pool.free_slot(b)
                self.slots[b] = None
        self._note_pool()
        return self.slots[b] is not None

    def _finish_or_grow(self, b: int) -> None:
        """Post-advance bookkeeping shared by decode ticks and prefill
        chunks: retire done / cache-exhausted slots, else page up for the
        next token write."""
        s = self.slots[b]
        out_of_cache = s.length >= self.cache_len and not self.allow_wrap
        if s.done or out_of_cache:
            self.finished[s.rid] = list(s.generated)
            self._c_finished.inc()
            if out_of_cache and not s.done:
                self.truncated.add(s.rid)
                self._c_truncated.inc()
            self.pool.free_slot(b)
            self.slots[b] = None
            self._note_pool()
            return
        self.ensure_pages(b, s.length + 1)

    def advance(self, sampled: list[int], active: list[bool] | None = None) -> None:
        """Account one decode step: grow lengths, collect samples, finish
        done slots, allocate pages crossed into (evicting on exhaustion).
        ``active`` (the mask ``step_inputs`` returned) skips slots that sat
        the tick out — occupied but mid-chunked-prefill."""
        for b, s in enumerate(self.slots):
            if s is None or (active is not None and not active[b]):
                continue
            s.length += 1
            if s.length >= len(s.prompt):
                # the step consuming the last prompt token (and every one
                # after it) produces a sampled continuation token
                s.generated.append(int(sampled[b]))
            self._finish_or_grow(b)

    # -- chunked-prefill interface -------------------------------------------
    def prefill_slots(self) -> list[int]:
        return [b for b, s in enumerate(self.slots) if s is not None and s.in_prefill]

    def decode_ready(self) -> list[int]:
        """Slots with an in-flight stream a prefill tick would stall."""
        return [b for b, s in enumerate(self.slots)
                if s is not None and not s.in_prefill]

    def should_prefill(self, consec_prefill: int, chunk_budget: int | None) -> bool:
        """Interleaving policy: run a prefill tick next?

        No prefill work -> never. No decode-ready streams to stall (or no
        budget cap) -> always. Otherwise cap consecutive prefill ticks at
        ``chunk_budget`` so no in-flight stream waits more than
        ``chunk_budget`` chunk calls between its tokens (property-tested in
        tests/test_serve_prefill.py)."""
        if not self.prefill_slots():
            return False
        if chunk_budget is None or not self.decode_ready():
            return True
        return consec_prefill < chunk_budget

    def prefill_budget(self, b: int) -> int:
        """Max prompt tokens slot ``b`` may ingest in the next chunk:
        its remaining prompt, clamped at the cache edge for non-wrapping
        (full-attention) caches — mirroring replay truncation."""
        s = self.slots[b]
        remaining = len(s.prompt) - s.length
        if not self.allow_wrap:
            remaining = min(remaining, self.cache_len - s.length)
        return max(0, remaining)

    def advance_prefill(self, fed: list[int], sampled: list[int]) -> None:
        """Account one chunked-prefill call: slot ``b`` ingested ``fed[b]``
        prompt tokens; ``sampled[b]`` is the continuation token its final
        fed token produced (used only when the chunk completes the prompt).
        """
        for b, s in enumerate(self.slots):
            if s is None or not fed[b]:
                continue
            assert s.in_prefill and s.length + fed[b] <= len(s.prompt)
            s.length += fed[b]
            if s.length >= len(s.prompt):
                s.generated.append(int(sampled[b]))
            self._finish_or_grow(b)

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def live_slots(self) -> list[int]:
        return [b for b, s in enumerate(self.slots) if s is not None]
