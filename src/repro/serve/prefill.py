"""Chunked prefill: ingest prompt tokens into the decode cache in one call.

``prefill_chunk`` runs a ``lax.scan`` of ``C`` single-token decode steps over
a (B, C) token block — per-slot start positions, per-slot token counts — so a
batch of prompts (or one chunk of each) lands in the cache as ONE compiled
program instead of C engine round-trips. Each inner step is *the* decode step
(``models.kvcache.decode_step``) with an ``active = t < n_tok`` slot mask:
slots whose chunk is shorter than ``C`` simply stop writing, and the ops run
for active slots are bitwise-identical to token-by-token teacher-forced
replay (tests/test_serve_prefill.py asserts diff == 0.0 on resident and
paged caches).

Chunking policy lives elsewhere: the scheduler decides *when* a prefill
chunk runs relative to decode ticks (serve/scheduler.py:should_prefill) and
the cost model decides *how large* a chunk fits in the decode-latency budget
(core/cost_model.py:choose_prefill_chunk). This module is only the dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import kvcache as KV


def prefill_chunk(params: dict, cache: dict, tokens: jax.Array,
                  pos: jax.Array, n_tok: jax.Array, cfg: ModelConfig, *,
                  gather_specs=None, kv_io=None) -> tuple[jax.Array, dict]:
    """Feed up to ``C`` prompt tokens per batch slot into the decode cache.

    Args:
      tokens: (B, C) int32 — slot b feeds ``tokens[b, :n_tok[b]]``; the tail
        is padding (ignored, cache untouched).
      pos:    (B,) int32 — cache position of each slot's first chunk token.
      n_tok:  (B,) int32 — tokens to ingest per slot (0 leaves the slot's
        cache and logits row untouched).

    Returns ``(last_logits, new_cache)`` where ``last_logits[b]`` is the
    logits produced by slot b's final fed token (position
    ``pos[b] + n_tok[b] - 1``) — the next-token distribution the engine
    samples from when the chunk completes the prompt — and zeros for slots
    with ``n_tok == 0``.
    """
    b, c = tokens.shape

    def body(carry, xs):
        cache, last = carry
        tok_t, t = xs  # (B,), ()
        active = t < n_tok
        logits, cache = KV.decode_step(
            params, cache, tok_t[:, None], pos + t, cfg,
            gather_specs=gather_specs, kv_io=kv_io, active=active,
        )
        last = jnp.where((t == n_tok - 1)[:, None], logits, last)
        return (cache, last), None

    last0 = jnp.zeros((b, cfg.vocab_size), jnp.dtype(cfg.dtype))
    (cache, last), _ = jax.lax.scan(
        body, (cache, last0), (tokens.T, jnp.arange(c, dtype=jnp.int32)))
    return last, cache
