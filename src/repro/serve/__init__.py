"""Serving subsystem: paged KV caches, continuous batching, decode engine.

Three modules mirror the training stack's plan->program split:

  * ``paging``   — page-table KV cache: hot window resident in HBM, cold
    pages in host memory, double-buffered h2d prefetch inside the decode
    scan (the serving twin of the training path's lazy per-chunk gathers);
  * ``scheduler`` — continuous batching: admit/evict/finish requests into
    batch slots with per-slot sequence lengths and page allocation against
    a bounded pool;
  * ``engine``   — drives ``step_builder.build_decode_step`` (resident or
    paged) over the scheduler's slot state, serving a request stream.

See docs/serving.md for the dataflow and the plan-knob meanings.
"""
from repro.serve.engine import DecodeEngine, EngineReport
from repro.serve.paging import (
    PagedKV,
    PagingSpec,
    choose_paging,
    init_paged_cache,
    paged_cache_specs,
    paged_to_resident,
)
from repro.serve.scheduler import ContinuousScheduler, PagePool, Request

__all__ = [
    "ContinuousScheduler",
    "DecodeEngine",
    "EngineReport",
    "PagePool",
    "PagedKV",
    "PagingSpec",
    "Request",
    "choose_paging",
    "init_paged_cache",
    "paged_cache_specs",
    "paged_to_resident",
]
