"""Serving subsystem: paged KV caches, continuous batching, decode engine.

Three modules mirror the training stack's plan->program split:

  * ``paging``   — page-table KV cache: hot window resident in HBM, cold
    pages in host memory, double-buffered h2d prefetch inside the decode
    scan (the serving twin of the training path's lazy per-chunk gathers);
  * ``prefill``  — chunked prefill: one compiled ``lax.scan`` of decode
    steps ingests a prompt block per call, bitwise-equal to token-by-token
    replay (``serve/prefill.py``);
  * ``scheduler`` — continuous batching: admit/evict/finish requests into
    batch slots with per-slot sequence lengths and page allocation against
    a bounded pool;
  * ``engine``   — drives ``step_builder.build_decode_step`` /
    ``build_prefill_step`` (resident or paged) over the scheduler's slot
    state behind the request API (``submit``/``run``/``stream``).

See docs/serving.md for the dataflow and the plan-knob meanings.
"""
from repro.serve.engine import DecodeEngine, EngineReport, TokenEvent
from repro.serve.paging import (
    PagedKV,
    PagingSpec,
    choose_paging,
    init_paged_cache,
    paged_cache_specs,
    paged_to_resident,
)
from repro.serve.prefill import prefill_chunk
from repro.serve.scheduler import ContinuousScheduler, PagePool, Request

__all__ = [
    "ContinuousScheduler",
    "DecodeEngine",
    "EngineReport",
    "PagePool",
    "PagedKV",
    "PagingSpec",
    "Request",
    "TokenEvent",
    "choose_paging",
    "init_paged_cache",
    "paged_cache_specs",
    "paged_to_resident",
    "prefill_chunk",
]
