"""Decode engine: continuous batching over a (resident or paged) decode step.

``DecodeEngine`` owns the compiled decode and prefill steps
(``step_builder.build_decode_step`` / ``build_prefill_step(chunk=C)``), a
``ContinuousScheduler``, and the live cache state. The public surface is the
request API: ``submit(requests)`` queues work, ``run(max_steps=...)`` drives
ticks until drained and returns an ``EngineReport``, ``stream()`` yields
``TokenEvent``s as slots produce tokens, and ``report()`` snapshots metrics
for callers that drive ``step_once()`` themselves (benchmarks/serve_load.py).

Each tick the engine

  1. admits queued requests into free batch slots (zeroing the slots' cache
     rows — mamba state is recurrent and MUST be reset; attention rows are
     reset for hygiene, masking already hides stale rows);
  2. decides prefill vs decode (``scheduler.should_prefill``): under chunked
     admission, prompts are ingested through the chunked-prefill program up
     to ``prefill_chunk`` tokens per slot per call, interleaved with decode
     ticks so at most ``chunk_budget`` consecutive prefill calls ever stall
     an in-flight stream; under ``"whole"`` admission the same program runs
     back-to-back until every prompt is resident (the stall-heavy baseline
     the load harness compares against); ``"replay"`` keeps the legacy
     teacher-forced path — prompt tokens fed one per tick through the decode
     step — as the fallback for attention-free configs;
  3. runs the compiled step (greedy sampling inside the program) and feeds
     the sampled tokens back to the scheduler, which finishes/evicts slots
     and allocates pages crossed into.

The engine is deliberately backend-agnostic: all placement decisions live in
the step artifacts (plan + paging spec), so the same loop drives a fully
HBM-resident cache or the host-paged one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Iterator

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.plan import MemoryPlan
from repro.obs.metrics import quantile as _quantile
from repro.serve.paging import PagingSpec, cache_partition_bytes
from repro.serve.scheduler import ContinuousScheduler, PagePool, Request


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One generated token, as yielded by ``DecodeEngine.stream``."""

    rid: int
    token: int
    index: int  # position in the request's generated sequence
    finished: bool  # True on the request's final token


@dataclasses.dataclass
class EngineReport:
    steps: int
    generated_tokens: int
    finished: dict[int, list[int]]
    rejected: dict[int, list[int]]
    evictions: int
    wall_s: float
    hbm_cache_bytes: int  # device-resident cache bytes (global)
    host_cache_bytes: int  # host-resident cold pages (global)
    resident_cache_bytes: int  # what the fully-resident layout would hold
    drained: bool = True  # False: max_steps hit with requests in flight
    pending: tuple[int, ...] = ()  # rids still queued/running at stop
    truncated: tuple[int, ...] = ()  # rids finished by cache exhaustion
    # -- per-request timing (wall-clock; inherently nondeterministic) --------
    ttft_s: dict[int, float] = dataclasses.field(default_factory=dict)
    request_latency_s: dict[int, float] = dataclasses.field(default_factory=dict)
    itl_s: tuple[float, ...] = ()  # inter-token gaps across all streams
    prefill_ticks: int = 0
    decode_ticks: int = 0
    admission: str = "replay"
    prefill_chunk: int = 0

    @property
    def hbm_reduction(self) -> float:
        """Resident-over-paged device cache footprint (>1 means paging
        freed HBM)."""
        return self.resident_cache_bytes / max(self.hbm_cache_bytes, 1)

    @property
    def p50_latency_s(self) -> float:
        return _quantile(list(self.request_latency_s.values()), 0.50)

    @property
    def p99_latency_s(self) -> float:
        return _quantile(list(self.request_latency_s.values()), 0.99)

    @property
    def p50_ttft_s(self) -> float:
        return _quantile(list(self.ttft_s.values()), 0.50)

    @property
    def p99_ttft_s(self) -> float:
        return _quantile(list(self.ttft_s.values()), 0.99)

    @property
    def p99_itl_s(self) -> float:
        """p99 in-flight decode latency: the tail of the wall-clock gaps
        between consecutive tokens of the same stream — what whole-prompt
        admission inflates and chunked prefill bounds."""
        return _quantile(list(self.itl_s), 0.99)

    def to_dict(self) -> dict:
        """The flat JSON form load harnesses record per mode — field for
        field (and rounding for rounding) what benchmarks/serve_load.py
        writes into BENCH_serve.json, so callers stop re-deriving the
        percentile math (the harness adds only the token checksum)."""
        return {
            "admission": self.admission,
            "prefill_chunk": self.prefill_chunk,
            "drained": self.drained,
            "steps": self.steps,
            "prefill_ticks": self.prefill_ticks,
            "decode_ticks": self.decode_ticks,
            "generated_tokens": self.generated_tokens,
            "finished_requests": len(self.finished),
            "evictions": self.evictions,
            "truncated": len(self.truncated),
            "rejected": len(self.rejected),
            # wall-clock measurements (jitter run to run)
            "wall_s": round(self.wall_s, 6),
            "tokens_per_s": round(
                self.generated_tokens / max(self.wall_s, 1e-9), 3),
            "p50_latency_s": round(self.p50_latency_s, 6),
            "p99_latency_s": round(self.p99_latency_s, 6),
            "p50_ttft_s": round(self.p50_ttft_s, 6),
            "p99_ttft_s": round(self.p99_ttft_s, 6),
            "p99_itl_s": round(self.p99_itl_s, 6),
        }


def _zero_slots(cache, mask: jax.Array):
    """Zero every cache leaf's rows for slots where ``mask`` is True.

    All decode-cache leaves carry the batch dim at axis 1 — (R, B, ...) —
    for both resident and paged layouts.
    """

    def one(x):
        m = mask.reshape((1, -1) + (1,) * (x.ndim - 2))
        return jnp.where(m, jnp.zeros((), x.dtype), x)

    return jax.tree.map(one, cache)


class DecodeEngine:
    """``admission`` selects how prompts enter the cache: ``"chunked"``
    (default for attentive configs) interleaves cost-model-sized prefill
    chunks with decode ticks; ``"whole"`` runs the same chunk program to
    completion before decode resumes (the fair stall-heavy baseline);
    ``"replay"`` (default for attention-free configs) teacher-forces the
    prompt through the decode step one token per tick. ``prefill_chunk``
    overrides the cost-model chunk size; ``chunk_budget`` caps consecutive
    prefill ticks while decode-ready streams wait (None = unbounded)."""

    def __init__(
        self,
        cfg: ModelConfig,
        plan: MemoryPlan,
        mesh,
        shape: ShapeConfig,
        params: Any,
        *,
        paging: PagingSpec | None = None,
        own_params: bool = False,
        admission: str | None = None,
        prefill_chunk: int | None = None,
        chunk_budget: int | None = 1,
        hw=None,
        telemetry: obs.Telemetry | None = None,
    ):
        from repro.models import kvcache as KVC
        from repro.train import step_builder as SB

        self.cfg, self.shape, self.paging = cfg, shape, paging
        # the engine's bookkeeping (tick counts, request counters, ITL) IS
        # its metrics registry — EngineReport reads back out of it — so an
        # engine without caller-provided telemetry still runs a real
        # registry (cheap host-side dict), just with span retention off
        tel = telemetry if telemetry is not None else obs.current_telemetry()
        if not tel.enabled:
            tel = obs.Telemetry(trace=False)
        self.tel = tel
        if admission is None:
            admission = "replay" if cfg.attention_free else "chunked"
        assert admission in ("replay", "chunked", "whole"), admission
        self.admission = admission
        self.chunk_budget = None if admission == "whole" else chunk_budget

        self.art = SB.build_decode_step(cfg, plan, mesh, shape,
                                        paging=paging, per_slot_pos=True)
        # the step donates its state (the paged cold store must not double
        # per step), so the engine owns the param buffers: place them per the
        # plan and detach from the caller's copies unless ownership was
        # explicitly handed over (own_params=True, the production path)
        params = jax.tree.map(jax.device_put, params,
                              self.art.state_shardings["params"])
        if not own_params:
            params = jax.tree.map(lambda x: x.copy(), params)
        cache_sh = self.art.state_shardings["cache"]
        if paging is None:
            cache = KVC.init_cache(cfg, shape.global_batch, shape.seq_len)
            cache = jax.tree.map(jax.device_put, cache, cache_sh)
        else:
            from repro.serve.paging import init_paged_cache

            cache = init_paged_cache(cfg, shape.global_batch, shape.seq_len,
                                     paging, shardings=cache_sh)
        self.state = {"params": params, "cache": cache}
        self._step = jax.jit(self.art.fn, donate_argnums=(0,))
        # out_shardings keep the cold pages in host memory through the reset:
        # without them the jitted zeroing would materialize the whole cold
        # store in device memory (a full h2d+d2h round trip per admission,
        # and an OOM whenever the cold store exceeds HBM — the exact regime
        # paging exists for; invisible on CPU CI where host == device)
        self._reset = jax.jit(_zero_slots, donate_argnums=(0,),
                              out_shardings=cache_sh)
        self._cache_sh = cache_sh

        cache_len = KVC.cache_len(cfg, shape.seq_len)
        if admission != "replay":
            if prefill_chunk is None:
                from repro.core.cost_model import choose_prefill_chunk
                from repro.core.hardware import LOCAL_CPU_HW, MeshSpec

                mspec = MeshSpec(tuple(mesh.devices.shape),
                                 tuple(mesh.axis_names))
                prefill_chunk = choose_prefill_chunk(
                    cfg, shape, mspec, hw or LOCAL_CPU_HW, spec=paging,
                    max_chunk=paging.page_size if paging else cache_len)
            self.prefill_chunk = max(1, min(int(prefill_chunk), cache_len))
            prefill_art = SB.build_prefill_step(
                cfg, plan, mesh, shape, chunk=self.prefill_chunk, paging=paging)
            self._prefill = jax.jit(prefill_art.fn, donate_argnums=(0,))
        else:
            self.prefill_chunk = 0
            self._prefill = None

        page_size = paging.page_size if paging else cache_len
        n_pages_per_slot = -(-cache_len // page_size)
        self.scheduler = ContinuousScheduler(
            n_slots=shape.global_batch,
            pool=PagePool(n_pages_per_slot * shape.global_batch),
            page_size=page_size,
            cache_len=cache_len,
            # ring caches (SWA) and O(1)-state models decode past the cache
            # length by slot reuse; full attention runs out of slots there
            allow_wrap=bool(cfg.sliding_window) or cfg.attention_free,
            registry=tel.registry,
        )
        # tick accounting lives in the registry (serve.ticks total plus the
        # phase-labeled split); `ticks`/`prefill_ticks`/`decode_ticks` below
        # are read-back properties over these counters
        reg = tel.registry
        self._c_ticks = reg.counter("serve.ticks")
        self._c_prefill_ticks = reg.counter("serve.ticks", phase="prefill")
        self._c_decode_ticks = reg.counter("serve.ticks", phase="decode")
        self._c_gen = reg.counter("serve.generated_tokens")
        self._h_itl = reg.histogram("serve.itl_s")
        self._c_fetch = reg.counter("serve.page_fetches")
        self._c_h2d = reg.counter("serve.h2d_bytes")
        # paged decode moves cold pages over the host link *inside* the
        # jitted step, so the traffic is priced statically (the same
        # inventory the cost model's t_page_fetch uses) and accounted per
        # decode tick
        if paging is not None:
            from repro.core.cost_model import (
                _attn_layer_count, page_fetch_bytes_per_step)
            from repro.core.hardware import MeshSpec

            mspec = MeshSpec(tuple(mesh.devices.shape),
                             tuple(mesh.axis_names))
            self._h2d_per_tick = int(
                page_fetch_bytes_per_step(cfg, shape, mspec, paging))
            self._fetches_per_tick = paging.n_cold * _attn_layer_count(cfg)
        else:
            self._h2d_per_tick = 0
            self._fetches_per_tick = 0
        # request-level timing (wall clock)
        self._consec_prefill = 0
        self._t0: float | None = None
        self._t_submit: dict[int, float] = {}
        self._t_first: dict[int, float] = {}
        self._t_finish: dict[int, float] = {}
        self._t_last_tok: dict[int, float] = {}
        self._gen_count: dict[int, int] = {}
        self._itl: list[float] = []

    # -- registry-backed tick accounting --------------------------------------
    # (writable only through the counters; the report is a view over them)
    @property
    def ticks(self) -> int:
        return int(self._c_ticks.value)

    @property
    def prefill_ticks(self) -> int:
        return int(self._c_prefill_ticks.value)

    @property
    def decode_ticks(self) -> int:
        return int(self._c_decode_ticks.value)

    # -- request API ---------------------------------------------------------
    def warmup(self) -> None:
        """Compile the decode (and prefill) programs ahead of traffic by
        running each once with an all-inactive batch — the active mask
        suppresses every cache write, so live state is untouched. Load
        harnesses call this so first-request latency measures the step,
        not the XLA compile."""
        bsz = self.shape.global_batch
        z = jnp.zeros((bsz,), jnp.int32)
        batch = {"tokens": z[:, None], "pos": z,
                 "active": jnp.zeros((bsz,), bool)}
        self.state, _ = self._step(self.state, batch)
        if self._prefill is not None:
            pb = {"tokens": jnp.zeros((bsz, self.prefill_chunk), jnp.int32),
                  "pos": z, "n_tok": z}
            self.state, _ = self._prefill(self.state, pb)
        self.state["cache"] = self._reset(self.state["cache"],
                                          jnp.zeros((bsz,), bool))

    def submit(self, requests: Iterable[Request]) -> None:
        """Queue requests; admission happens on subsequent ticks."""
        now = time.time()
        if self._t0 is None:
            self._t0 = now
        reqs = list(requests)
        self.scheduler.submit(reqs)
        for r in reqs:
            self._t_submit.setdefault(r.rid, now)

    def step_once(self) -> None:
        """One engine tick: admit, then one prefill chunk or one decode step
        (``scheduler.should_prefill`` arbitrates under chunked admission)."""
        sched = self.scheduler
        admitted = sched.admit()
        if admitted:
            mask = jnp.zeros((self.shape.global_batch,), bool)
            mask = mask.at[jnp.asarray(admitted)].set(True)
            self.state["cache"] = self._reset(self.state["cache"], mask)
        if (self._prefill is not None
                and sched.should_prefill(self._consec_prefill, self.chunk_budget)):
            with self.tel.tracer.span("serve.prefill_tick"):
                self._prefill_tick()
            self._consec_prefill += 1
        else:
            with self.tel.tracer.span("serve.decode_tick"):
                self._decode_tick()
            self._consec_prefill = 0
        self._c_ticks.inc()
        self._note_progress()

    # retained alias: one tick of the pre-redesign surface
    tick = step_once

    def run(self, requests: Iterable[Request] | None = None,
            max_steps: int = 10_000) -> EngineReport:
        """Drive ticks until drained (or ``max_steps``); returns the report."""
        if requests is not None:
            self.submit(requests)
        sched = self.scheduler
        steps = 0
        while not sched.idle and steps < max_steps:
            self.step_once()
            steps += 1
        return self.report(steps=steps)

    def stream(self, requests: Iterable[Request] | None = None,
               max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Tick the engine, yielding each generated token as a TokenEvent.

        Tokens are emitted in tick order, interleaved across requests
        (continuous batching). An evicted request's replayed tokens are not
        re-emitted — greedy decode regenerates them identically."""
        if requests is not None:
            self.submit(requests)
        sched = self.scheduler
        emitted: dict[int, int] = {}

        def drain() -> Iterator[TokenEvent]:
            live = {s.rid: (s.generated, False)
                    for s in sched.slots if s is not None}
            done = {rid: (toks, True) for rid, toks in sched.finished.items()}
            for rid, (toks, fin) in {**live, **done}.items():
                start = emitted.get(rid, 0)
                for i in range(start, len(toks)):
                    yield TokenEvent(rid, int(toks[i]), i,
                                     fin and i == len(toks) - 1)
                emitted[rid] = max(start, len(toks))

        steps = 0
        while not sched.idle and steps < max_steps:
            self.step_once()
            steps += 1
            yield from drain()

    # -- internal ticks -------------------------------------------------------
    def _decode_tick(self) -> None:
        sched = self.scheduler
        toks, poss, active = sched.step_inputs(
            replay_prefill=self.admission == "replay")
        if not any(active):
            return  # every occupied slot is mid-prefill: nothing to decode
        batch = {
            "tokens": jnp.asarray(toks, jnp.int32)[:, None],
            "pos": jnp.asarray(poss, jnp.int32),
            "active": jnp.asarray(active),
        }
        self.state, nxt = self._step(self.state, batch)
        sched.advance([int(t) for t in jax.device_get(nxt)], active)
        self._c_decode_ticks.inc()
        if self._fetches_per_tick:
            self._c_fetch.inc(self._fetches_per_tick)
            self._c_h2d.inc(self._h2d_per_tick)

    def _prefill_tick(self) -> None:
        sched = self.scheduler
        chunk = self.prefill_chunk
        bsz = self.shape.global_batch
        # page up BEFORE any cache write, so pool-pressure evictions and
        # rejections land before the chunk runs (an evicted slot restarts
        # from its prompt; its partial rows are zeroed on re-admission)
        for b in list(sched.prefill_slots()):
            s = sched.slots[b]
            if s is None:
                continue
            sched.ensure_pages(b, s.length + min(chunk, sched.prefill_budget(b)))
        # assemble AFTER all ensures: an ensure may have evicted another
        # prefill candidate, and a half-assembled batch would feed its rows
        toks = [[0] * chunk for _ in range(bsz)]
        pos = [0] * bsz
        n_tok = [0] * bsz
        for b in sched.prefill_slots():
            s = sched.slots[b]
            n_b = min(chunk, sched.prefill_budget(b))
            if n_b <= 0:
                continue
            toks[b][:n_b] = s.prompt[s.length:s.length + n_b]
            pos[b] = s.length
            n_tok[b] = n_b
        if not any(n_tok):
            return
        batch = {
            "tokens": jnp.asarray(toks, jnp.int32),
            "pos": jnp.asarray(pos, jnp.int32),
            "n_tok": jnp.asarray(n_tok, jnp.int32),
        }
        self.state, nxt = self._prefill(self.state, batch)
        sched.advance_prefill(n_tok, [int(t) for t in jax.device_get(nxt)])
        self._c_prefill_ticks.inc()

    # -- timing ---------------------------------------------------------------
    def _note_progress(self) -> None:
        now = time.time()
        sched = self.scheduler
        counts = {rid: len(toks) for rid, toks in sched.finished.items()}
        counts.update({s.rid: len(s.generated)
                       for s in sched.slots if s is not None})
        for rid, n in counts.items():
            seen = self._gen_count.get(rid, 0)
            if n > seen:
                self._c_gen.inc(n - seen)
                if rid not in self._t_first and rid in self._t_submit:
                    self._t_first[rid] = now
                if rid in self._t_last_tok:
                    # a gap per tick that produced tokens for this stream —
                    # the in-flight latency chunked prefill exists to bound
                    gap = now - self._t_last_tok[rid]
                    self._itl.append(gap)
                    self._h_itl.observe(gap)
                self._t_last_tok[rid] = now
                self._gen_count[rid] = n
            elif n < seen:
                self._gen_count[rid] = n  # evicted: replaying from scratch
        for rid in sched.finished:
            self._t_finish.setdefault(rid, now)
        for rid in sched.rejected:
            self._t_finish.setdefault(rid, now)

    # -- reporting -------------------------------------------------------------
    def report(self, steps: int | None = None) -> EngineReport:
        """Metrics snapshot — callable mid-flight by harnesses that drive
        ``step_once`` themselves."""
        sched = self.scheduler
        parts = cache_partition_bytes(
            self.cfg, self.shape.global_batch, self.shape.seq_len, self.paging)
        resident = cache_partition_bytes(
            self.cfg, self.shape.global_batch, self.shape.seq_len, None)
        pending = tuple(sorted(
            {r.rid for r in sched.queue}
            | {s.rid for s in sched.slots if s is not None}))
        t0 = self._t0 if self._t0 is not None else time.time()
        latency = {rid: self._t_finish[rid] - self._t_submit[rid]
                   for rid in self._t_finish if rid in self._t_submit}
        ttft = {rid: self._t_first[rid] - self._t_submit[rid]
                for rid in self._t_first if rid in self._t_submit}
        return EngineReport(
            drained=sched.idle,
            pending=pending,
            truncated=tuple(sorted(sched.truncated)),
            steps=self.ticks if steps is None else steps,
            generated_tokens=sum(len(v) for v in sched.finished.values()),
            finished=dict(sched.finished),
            rejected=dict(sched.rejected),
            evictions=sched.evictions,
            wall_s=time.time() - t0,
            hbm_cache_bytes=parts["hbm"] + parts["transient"],
            host_cache_bytes=parts["host"],
            resident_cache_bytes=resident["hbm"],
            ttft_s=ttft,
            request_latency_s=latency,
            itl_s=tuple(self._itl),
            prefill_ticks=self.prefill_ticks,
            decode_ticks=self.decode_ticks,
            admission=self.admission,
            prefill_chunk=self.prefill_chunk,
        )
