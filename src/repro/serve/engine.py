"""Decode engine: continuous batching over a (resident or paged) decode step.

``DecodeEngine`` owns the compiled step (``step_builder.build_decode_step``),
a ``ContinuousScheduler``, and the live cache state. Each tick it

  1. admits queued requests into free batch slots (zeroing the slots' cache
     rows — mamba state is recurrent and MUST be reset; attention rows are
     reset for hygiene, masking already hides stale rows);
  2. assembles per-slot (token, position) inputs — prefill is teacher-forced
     through the decode step at per-slot positions, so freshly admitted
     requests replay their prompt while older slots keep generating
     (continuous batching, no global barrier between requests);
  3. runs the compiled step (greedy sampling inside the program) and feeds
     the sampled tokens back to the scheduler, which finishes/evicts slots
     and allocates pages crossed into.

The engine is deliberately backend-agnostic: all placement decisions live in
the step artifacts (plan + paging spec), so the same loop drives a fully
HBM-resident cache or the host-paged one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.plan import MemoryPlan
from repro.serve.paging import PagingSpec, cache_partition_bytes
from repro.serve.scheduler import ContinuousScheduler, PagePool, Request


@dataclasses.dataclass
class EngineReport:
    steps: int
    generated_tokens: int
    finished: dict[int, list[int]]
    rejected: dict[int, list[int]]
    evictions: int
    wall_s: float
    hbm_cache_bytes: int  # device-resident cache bytes (global)
    host_cache_bytes: int  # host-resident cold pages (global)
    resident_cache_bytes: int  # what the fully-resident layout would hold
    drained: bool = True  # False: max_steps hit with requests in flight
    pending: tuple[int, ...] = ()  # rids still queued/running at stop
    truncated: tuple[int, ...] = ()  # rids finished by cache exhaustion

    @property
    def hbm_reduction(self) -> float:
        """Resident-over-paged device cache footprint (>1 means paging
        freed HBM)."""
        return self.resident_cache_bytes / max(self.hbm_cache_bytes, 1)


def _zero_slots(cache, mask: jax.Array):
    """Zero every cache leaf's rows for slots where ``mask`` is True.

    All decode-cache leaves carry the batch dim at axis 1 — (R, B, ...) —
    for both resident and paged layouts.
    """

    def one(x):
        m = mask.reshape((1, -1) + (1,) * (x.ndim - 2))
        return jnp.where(m, jnp.zeros((), x.dtype), x)

    return jax.tree.map(one, cache)


class DecodeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        plan: MemoryPlan,
        mesh,
        shape: ShapeConfig,
        params: Any,
        *,
        paging: PagingSpec | None = None,
        own_params: bool = False,
    ):
        from repro.models import kvcache as KVC
        from repro.train import step_builder as SB

        self.cfg, self.shape, self.paging = cfg, shape, paging
        self.art = SB.build_decode_step(cfg, plan, mesh, shape,
                                        paging=paging, per_slot_pos=True)
        # the step donates its state (the paged cold store must not double
        # per step), so the engine owns the param buffers: place them per the
        # plan and detach from the caller's copies unless ownership was
        # explicitly handed over (own_params=True, the production path)
        params = jax.tree.map(jax.device_put, params,
                              self.art.state_shardings["params"])
        if not own_params:
            params = jax.tree.map(lambda x: x.copy(), params)
        cache_sh = self.art.state_shardings["cache"]
        if paging is None:
            cache = KVC.init_cache(cfg, shape.global_batch, shape.seq_len)
            cache = jax.tree.map(jax.device_put, cache, cache_sh)
        else:
            from repro.serve.paging import init_paged_cache

            cache = init_paged_cache(cfg, shape.global_batch, shape.seq_len,
                                     paging, shardings=cache_sh)
        self.state = {"params": params, "cache": cache}
        self._step = jax.jit(self.art.fn, donate_argnums=(0,))
        # out_shardings keep the cold pages in host memory through the reset:
        # without them the jitted zeroing would materialize the whole cold
        # store in device memory (a full h2d+d2h round trip per admission,
        # and an OOM whenever the cold store exceeds HBM — the exact regime
        # paging exists for; invisible on CPU CI where host == device)
        self._reset = jax.jit(_zero_slots, donate_argnums=(0,),
                              out_shardings=cache_sh)
        self._cache_sh = cache_sh

        cache_len = KVC.cache_len(cfg, shape.seq_len)
        page_size = paging.page_size if paging else cache_len
        n_pages_per_slot = -(-cache_len // page_size)
        self.scheduler = ContinuousScheduler(
            n_slots=shape.global_batch,
            pool=PagePool(n_pages_per_slot * shape.global_batch),
            page_size=page_size,
            cache_len=cache_len,
            # ring caches (SWA) and O(1)-state models decode past the cache
            # length by slot reuse; full attention runs out of slots there
            allow_wrap=bool(cfg.sliding_window) or cfg.attention_free,
        )

    # -- one engine tick -----------------------------------------------------
    def tick(self) -> None:
        sched = self.scheduler
        admitted = sched.admit()
        if admitted:
            mask = jnp.zeros((self.shape.global_batch,), bool)
            mask = mask.at[jnp.asarray(admitted)].set(True)
            self.state["cache"] = self._reset(self.state["cache"], mask)
        toks, poss, _ = sched.step_inputs()
        batch = {
            "tokens": jnp.asarray(toks, jnp.int32)[:, None],
            "pos": jnp.asarray(poss, jnp.int32),
        }
        self.state, nxt = self._step(self.state, batch)
        sched.advance([int(t) for t in jax.device_get(nxt)])

    def run(self, requests: Iterable[Request], max_steps: int = 10_000) -> EngineReport:
        sched = self.scheduler
        sched.submit(requests)
        t0 = time.time()
        steps = 0
        while not sched.idle and steps < max_steps:
            self.tick()
            steps += 1
        parts = cache_partition_bytes(
            self.cfg, self.shape.global_batch, self.shape.seq_len, self.paging)
        resident = cache_partition_bytes(
            self.cfg, self.shape.global_batch, self.shape.seq_len, None)
        pending = tuple(sorted(
            {r.rid for r in sched.queue}
            | {s.rid for s in sched.slots if s is not None}))
        return EngineReport(
            drained=sched.idle,
            pending=pending,
            truncated=tuple(sorted(sched.truncated)),
            steps=steps,
            generated_tokens=sum(len(v) for v in sched.finished.values()),
            finished=dict(sched.finished),
            rejected=dict(sched.rejected),
            evictions=sched.evictions,
            wall_s=time.time() - t0,
            hbm_cache_bytes=parts["hbm"] + parts["transient"],
            host_cache_bytes=parts["host"],
            resident_cache_bytes=resident["hbm"],
        )
