"""Page-table KV cache: hot window in HBM, cold pages in host memory.

Each attention position's (B, S, n_kv, hd) decode cache is split along the
sequence dimension into fixed-size pages. Two physical stores back it:

  * ``k_hot``/``v_hot`` — an HBM ring of the last ``hot_window`` slots
    (``n_hot`` pages). Every decoded token is written here at
    ``slot % hot_window``, so the most recent pages are always servable
    without touching the host link.
  * ``k_cold``/``v_cold`` — the canonical full cache in host memory
    (``compat.host_memory_kind``), written through every step (a one-token
    update). Cold is always correct, which is what makes eviction implicit:
    a hot ring row may be overwritten ``hot_window`` steps later without any
    flush, because the canonical value already lives in cold.

At attention time the per-layer full cache is reconstructed page by page
inside the decode repeat scan (the serving twin of ``Run.lazy_gather``'s
per-chunk weight gathers): pages inside the hot window are static slices of
the HBM ring; pages outside it are fetched h2d with ``jax.device_put`` under
``lax.cond``, double-buffered — each fetch is ordered after the page-before-
last via ``optimization_barrier`` so at most two transfers are in flight and
XLA cannot hoist the fetch pipeline out of the scan (the same anti-hoist
rationale as ``models.model.gather_weights``).

Exactness: the gathered cache equals the resident cache *elementwise on every
attended slot*. Hot-ring rows belonging to masked slots may hold stale tokens
(ring reuse), but the decode mask is additive ``NEG_INF`` — their softmax
weight underflows to exactly 0.0 in fp32, so paged logits are bit-identical
to resident logits (tests/test_serve_paging.py asserts zero difference).

Mamba positions carry O(1) recurrent state and stay fully HBM-resident, as
does encoder-decoder cross-attention K/V (prefill-computed, read-only).

The ring-correctness invariant requires ``n_pages % n_hot == 0`` for
sliding-window (ring) caches — a page and the hot slot it maps to must agree
on which logical page is the most recently written one; ``choose_paging``
enforces the divisibility.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig
from repro.models import kvcache as KV


@dataclasses.dataclass(frozen=True)
class PagingSpec:
    """Page geometry for one serve configuration.

    ``n_hot`` counts hot (HBM-resident) pages; the remaining
    ``n_pages - n_hot`` cold pages are what ``MemoryPlan.n_host`` records for
    serve plans (core/serve_plan.py).
    """

    page_size: int  # tokens per page (P)
    n_pages: int  # pages spanning the cache length
    n_hot: int  # pages of the hot window (>= 1, divides n_pages)

    def __post_init__(self):
        assert self.page_size >= 1 and self.n_pages >= 1
        assert 1 <= self.n_hot <= self.n_pages
        assert self.n_pages % self.n_hot == 0, (
            "hot window must tile the page ring (SWA ring-slot correctness)")

    @property
    def cache_len(self) -> int:
        return self.page_size * self.n_pages

    @property
    def hot_window(self) -> int:
        return self.page_size * self.n_hot

    @property
    def n_cold(self) -> int:
        return self.n_pages - self.n_hot


def choose_paging(cache_len: int, page_size: int, n_hot: int) -> PagingSpec:
    """Clamp (page_size, n_hot) to a valid spec for ``cache_len``.

    page_size is reduced to the largest divisor of ``cache_len`` not
    exceeding the request; n_hot to the largest divisor of the resulting
    page count. Keeps planner searches total — every request maps to some
    legal geometry.
    """
    page_size = max(1, min(page_size, cache_len))
    while cache_len % page_size:
        page_size -= 1
    n_pages = cache_len // page_size
    n_hot = max(1, min(n_hot, n_pages))
    while n_pages % n_hot:
        n_hot -= 1
    return PagingSpec(page_size=page_size, n_pages=n_pages, n_hot=n_hot)


# ---------------------------------------------------------------------------
# Paged cache pytrees
# ---------------------------------------------------------------------------
def paged_cache_specs(cfg: ModelConfig, batch: int, seq_len: int,
                      spec: PagingSpec) -> dict:
    """ShapeDtypeStruct pytree for the paged decode cache.

    Attention positions split into hot ring + cold store; mamba (and encdec
    cross-attention) entries are identical to the resident layout.
    """
    base = KV.cache_specs(cfg, batch, seq_len)
    assert spec.cache_len == KV.cache_len(cfg, seq_len), (
        f"paging spec covers {spec.cache_len} slots, cache has "
        f"{KV.cache_len(cfg, seq_len)}")
    out: dict[str, Any] = {}
    for pos, entry in base.items():
        if "k" not in entry:
            out[pos] = dict(entry)
            continue
        kv = entry["k"]  # (R, B, S, n_kv, hd)
        r, b, _, n_kv, hd = kv.shape
        hot = jax.ShapeDtypeStruct((r, b, spec.hot_window, n_kv, hd), kv.dtype)
        new = {"k_hot": hot, "v_hot": hot, "k_cold": kv, "v_cold": kv}
        for extra in ("xk", "xv"):  # encdec cross-attention stays resident
            if extra in entry:
                new[extra] = entry[extra]
        out[pos] = new
    return out


def init_paged_cache(cfg: ModelConfig, batch: int, seq_len: int,
                     spec: PagingSpec, shardings=None):
    """Zeros matching ``paged_cache_specs``; ``shardings`` (same pytree of
    NamedSharding) places cold leaves in host memory."""
    specs = paged_cache_specs(cfg, batch, seq_len, spec)
    zeros = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if shardings is None:
        return zeros
    return jax.tree.map(jax.device_put, zeros, shardings)


def paged_to_resident(cache: dict) -> dict:
    """Resident-layout view of a paged cache.

    Under write-through (``PagedKV(flush=False)``) cold is canonical at every
    step. Under page-boundary flush (the default) cold is canonical for every
    *completed* page; each slot's current write page is only in the hot ring
    until the slot crosses the next page boundary.
    """
    out = {}
    for pos, entry in cache.items():
        if "k_cold" not in entry:
            out[pos] = dict(entry)
            continue
        new = {"k": entry["k_cold"], "v": entry["v_cold"]}
        for extra in ("xk", "xv"):
            if extra in entry:
                new[extra] = entry[extra]
        out[pos] = new
    return out


# ---------------------------------------------------------------------------
# Decode-time cache I/O (the kv_io hook of models.kvcache.decode_step)
# ---------------------------------------------------------------------------
class PagedKV:
    """Paged cache I/O for one decode step.

    ``fetch_sharding`` (optional NamedSharding of one fetched page,
    device-memory) makes the h2d fetch an explicit op inside the scan; when
    None the transfer is left to XLA's memory-space propagation (tests that
    construct PagedKV without a mesh).

    ``flush`` selects the cold-store write policy. ``True`` (default) is the
    page-boundary flush of docs/serving.md §5: the hot ring is the only
    per-token write target, and a completed page is copied hot→cold once per
    ``page_size`` steps — one d2h burst per page instead of a one-token d2h
    every step. ``False`` keeps the original write-through (cold updated
    every token), retained as the reference policy the flush equivalence
    test compares against.

    ``use_kernel`` selects the attention path the ``attend`` hook takes
    (docs/kernels.md). ``None`` (default) auto-resolves: the fused Pallas
    paged-attention kernel when the package dispatches to Pallas *and* the
    stores are device-visible (``fetch_sharding is None``); the lax
    gather-then-attend rebuild otherwise. The explicit-sharding exclusion is
    deliberate: the step-builder path pins cold leaves in host memory and
    shards the cache under GSPMD, and a ``pallas_call`` neither partitions
    under GSPMD nor reads a host memory space — there the double-buffered
    per-page fetch pipeline *is* the right engine (and the h2d calibration
    census depends on its lowered form). ``True``/``False`` force the path
    (differential tests drive both sides of the parity contract).
    """

    entry_keys = ("k_hot", "v_hot", "k_cold", "v_cold")

    def __init__(self, spec: PagingSpec, fetch_sharding=None,
                 flush: bool = True, use_kernel: bool | None = None):
        self.spec = spec
        self.fetch_sharding = fetch_sharding
        self.flush = flush
        if use_kernel is None:
            from repro.kernels import pallas_kernels_active

            use_kernel = pallas_kernels_active() and fetch_sharding is None
        self.use_kernel = use_kernel

    # -- page residency -----------------------------------------------------
    def _hot_mask(self, wp: jax.Array, p: int, sliding: bool) -> jax.Array:
        """Is logical page ``p`` fully servable from the hot ring for a slot
        at write page ``wp``? Shape follows ``wp`` (scalar, or (B,) per-slot).

        Full attention: the last ``n_hot`` pages including the current write
        page (its unwritten rows are masked, so stale ring content there is
        invisible). Sliding-window ring caches differ in steady state: every
        cache slot is *valid*, and the current write page's not-yet-rewritten
        slots hold values from one ring cycle ago — older than the hot
        window — so only the ``n_hot - 1`` most recent *fully written* pages
        are servable; the write page itself needs cold rows (all of them
        under write-through; the not-yet-rewritten tail under flush).
        """
        s = self.spec
        if sliding:
            d = (wp - p) % s.n_pages
            return (d >= 1) & (d < s.n_hot)
        return (wp >= p) & (wp - p < s.n_hot)

    def _page_is_hot(self, wp: jax.Array, p: int, sliding: bool) -> jax.Array:
        """Scalar ALL-reduction of ``_hot_mask`` (a page is fetched unless
        hot for every batch row)."""
        return jnp.all(self._hot_mask(wp, p, sliding))

    def _take_hot_rows(self, wp: jax.Array, slot: jax.Array, p: int,
                       sliding: bool) -> jax.Array:
        """Flush-mode row-level residency of page ``p``: True where the hot
        ring holds the canonical value, False where cold does.

        Full attention: the write page has no canonical cold copy (it is
        flushed only on completion), so the whole hot window — write page
        included — serves from the ring; unwritten rows are masked. Sliding
        rings additionally split the write page by row: rows the current
        cycle already rewrote (``row <= slot % P``) live in the ring, the
        remaining rows still hold *last* cycle's values, flushed to cold when
        that cycle completed the page.

        Returns a rank-2 mask broadcastable against the page's (B, P) leading
        axes: (B-or-1, 1) for full attention, (B-or-1, P) for sliding rings.
        """
        s = self.spec
        if not sliding:
            mask = self._hot_mask(wp, p, sliding)  # write page included
            return mask.reshape((-1, 1))  # (B, 1) or (1, 1)
        d = jnp.asarray((wp - p) % s.n_pages).reshape((-1,))  # (B,) or (1,)
        full = (d >= 1) & (d < s.n_hot)
        rows = jnp.arange(s.page_size)
        written = rows[None, :] <= jnp.asarray(slot % s.page_size).reshape((-1, 1))
        return full[:, None] | ((d == 0)[:, None] & written)

    def _gather(self, hot: jax.Array, cold: jax.Array, wp: jax.Array,
                slot: jax.Array, sliding: bool) -> jax.Array:
        """Reconstruct the full (B, S, n_kv, hd) cache from hot ring + cold
        pages, double-buffered prefetch ordering on the cold fetches.

        Write-through keeps the per-page all-or-nothing ``lax.cond`` (cold is
        always canonical, so any page may be fetched whole). Flush mode keeps
        the all-hot fast path as a ``lax.cond`` but resolves mixed pages with
        a per-slot (sliding: per-row) select between ring and fetched cold."""
        s = self.spec
        P = s.page_size
        pages: list[jax.Array] = []
        for p in range(s.n_pages):
            row0 = (p % s.n_hot) * P
            hot_rows = jax.lax.slice_in_dim(hot, row0, row0 + P, axis=1)
            cold_rows = jax.lax.slice_in_dim(cold, p * P, (p + 1) * P, axis=1)
            if len(pages) >= 2:
                # double buffer: this fetch may start only once the
                # page-before-last materialized (≤ 2 transfers in flight),
                # and the barrier pins the pipeline inside the repeat scan
                cold_rows, _ = optimization_barrier((cold_rows, pages[-2]))
            fetch = self.fetch_sharding

            def from_cold(h, c, _sh=fetch):
                return c if _sh is None else jax.device_put(c, _sh)

            if not self.flush:
                pages.append(jax.lax.cond(
                    self._page_is_hot(wp, p, sliding),
                    lambda h, c: h, from_cold, hot_rows, cold_rows))
                continue

            take_hot = self._take_hot_rows(wp, slot, p, sliding)  # (B?, P?)
            sel = take_hot[..., None, None]  # broadcast over (B, P, kv, hd)

            def mixed(h, c, _sh=fetch, _sel=sel):
                c = c if _sh is None else jax.device_put(c, _sh)
                return jnp.where(_sel, h, c)

            pages.append(jax.lax.cond(
                jnp.all(take_hot), lambda h, c: h, mixed, hot_rows, cold_rows))
        return jnp.concatenate(pages, axis=1)

    # -- page-boundary flush --------------------------------------------------
    def _flush_cold(self, cold: jax.Array, hot: jax.Array, slot: jax.Array,
                    active: jax.Array | None) -> jax.Array:
        """Copy each slot's just-completed page hot→cold when the slot sits
        on a page boundary (``(slot + 1) % page_size == 0``); no cold write
        otherwise. The ring row of cache row ``r`` is exactly
        ``r % hot_window`` (``hot_window`` divides the ring), which keeps the
        per-slot source lookup a plain modular gather."""
        s = self.spec
        P, W = s.page_size, s.hot_window
        if jnp.ndim(slot) == 0:
            wp = slot // P

            def do_flush(c, h):
                page = jax.lax.dynamic_slice_in_dim(h, (wp % s.n_hot) * P, P, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(c, page, wp * P, axis=1)

            return jax.lax.cond((slot + 1) % P == 0, do_flush,
                                lambda c, h: c, cold, hot)

        boundary = (slot + 1) % P == 0
        if active is not None:
            boundary = boundary & active
        wp = slot // P
        rows = jnp.arange(cold.shape[1])

        def do_flush(c, h):
            src = jnp.take(h, rows % W, axis=1)  # (B, S, ...) ring view
            sel = boundary[:, None] & (rows[None, :] // P == wp[:, None])
            return jnp.where(sel.reshape(sel.shape + (1,) * (c.ndim - 2)), src, c)

        return jax.lax.cond(jnp.any(boundary), do_flush,
                            lambda c, h: c, cold, hot)

    # -- the kv_io hook -------------------------------------------------------
    def _write(self, entry: dict, k: jax.Array, v: jax.Array,
               pos: jax.Array, cfg: ModelConfig,
               active: jax.Array | None):
        """The per-token cache write shared by both attention paths:
        hot-ring write plus flush/write-through cold update. Returns
        ``(hot_k, hot_v, cold_k, cold_v, slot, wp, sliding)``."""
        s = self.spec
        s_kv = entry["k_cold"].shape[1]
        assert s_kv == s.cache_len, (s_kv, s.cache_len)
        sliding = bool(cfg.sliding_window)
        slot = pos % s_kv if sliding else pos
        # hot ring at slot % W is the per-token write target
        hot_k = KV.write_slot(entry["k_hot"], k, slot % s.hot_window, mask=active)
        hot_v = KV.write_slot(entry["v_hot"], v, slot % s.hot_window, mask=active)
        if self.flush:
            # cold receives a completed page once per page_size steps
            cold_k = self._flush_cold(entry["k_cold"], hot_k, slot, active)
            cold_v = self._flush_cold(entry["v_cold"], hot_v, slot, active)
        else:
            # write-through: canonical cold updated every token
            cold_k = KV.write_slot(entry["k_cold"], k, slot, mask=active)
            cold_v = KV.write_slot(entry["v_cold"], v, slot, mask=active)
        return hot_k, hot_v, cold_k, cold_v, slot, slot // s.page_size, sliding

    def update_and_fetch(self, entry: dict, k: jax.Array, v: jax.Array,
                         pos: jax.Array, cfg: ModelConfig,
                         active: jax.Array | None = None):
        hot_k, hot_v, cold_k, cold_v, slot, wp, sliding = self._write(
            entry, k, v, pos, cfg, active)
        full_k = self._gather(hot_k, cold_k, wp, slot, sliding)
        full_v = self._gather(hot_v, cold_v, wp, slot, sliding)
        mask = KV.decode_mask(pos, self.spec.cache_len, sliding)
        new_entry = {"k_hot": hot_k, "v_hot": hot_v,
                     "k_cold": cold_k, "v_cold": cold_v}
        return full_k, full_v, mask, new_entry

    def _row_residency(self, wp: jax.Array, slot: jax.Array, sliding: bool,
                       batch: int) -> jax.Array:
        """(B, S) row-level residency the kernel's in-pass select consumes:
        True where the hot ring holds the row the lax ``_gather`` would take.

        Flush mode concatenates ``_take_hot_rows`` per page; write-through
        broadcasts the all-or-nothing ``_page_is_hot`` scalar (``_gather``'s
        ``lax.cond`` at row granularity — identical elementwise, and on
        masked stale rows any choice is absorbed by the NEG_INF mask).
        """
        s = self.spec
        cols = []
        for p in range(s.n_pages):
            if self.flush:
                take = self._take_hot_rows(wp, slot, p, sliding)
            else:
                take = self._page_is_hot(wp, p, sliding).reshape((1, 1))
            cols.append(jnp.broadcast_to(take, (batch, s.page_size)))
        return jnp.concatenate(cols, axis=1)

    def attend(self, entry: dict, q: jax.Array, k: jax.Array, v: jax.Array,
               pos: jax.Array, cfg: ModelConfig,
               active: jax.Array | None = None):
        """Fused write+attend hook (models.kvcache._decode_attention).

        With ``use_kernel`` the Pallas paged-attention kernel streams
        hot-ring slices and cold-page tiles straight into the attention
        pass — the gathered full cache never materializes. Without it,
        defers to ``update_and_fetch`` + ``_masked_decode_attn`` (the lax
        rebuild, which the parity tests hold the kernel bitwise against).
        Returns ``(out (B, 1, Hq, hd), new_entry)``.
        """
        if not self.use_kernel:
            full_k, full_v, mask, new_entry = self.update_and_fetch(
                entry, k, v, pos, cfg, active=active)
            return KV._masked_decode_attn(q, full_k, full_v, mask), new_entry
        from repro.kernels import decode_paged_attention

        hot_k, hot_v, cold_k, cold_v, slot, wp, sliding = self._write(
            entry, k, v, pos, cfg, active)
        b = q.shape[0]
        sel = self._row_residency(wp, slot, sliding, b)
        mask = KV.decode_mask(pos, self.spec.cache_len, sliding)
        mask = jnp.broadcast_to(mask.astype(jnp.float32),
                                (b, self.spec.cache_len))
        out = decode_paged_attention(q, hot_k, hot_v, cold_k, cold_v,
                                     sel, mask, n_hot=self.spec.n_hot)
        new_entry = {"k_hot": hot_k, "v_hot": hot_v,
                     "k_cold": cold_k, "v_cold": cold_v}
        return out, new_entry


# ---------------------------------------------------------------------------
# Accounting (serve_plan / examples / fidelity rows)
# ---------------------------------------------------------------------------
def cache_partition_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                          spec: PagingSpec | None) -> dict[str, int]:
    """Global bytes of the decode cache by residence tier.

    Keys: ``hbm`` (hot rings + mamba/cross-attn state), ``host`` (cold
    pages), ``transient`` (one attention position's gathered full cache —
    the largest per-layer reconstruction live during its attention). A
    ``spec`` of None prices the resident layout (everything hbm, no
    transient).
    """
    base = KV.cache_specs(cfg, batch, seq_len)
    hbm = host = transient = 0
    for entry in base.values():
        for name, sd in entry.items():
            nbytes = 1
            for d in sd.shape:
                nbytes *= d
            nbytes *= sd.dtype.itemsize
            if spec is None or name not in ("k", "v"):
                hbm += nbytes
                continue
            hbm += nbytes * spec.n_hot // spec.n_pages  # hot ring
            host += nbytes  # canonical cold store
            # per-repeat gathered reconstruction: (B, S, kv, hd) x {k, v}
            transient = max(transient, 2 * nbytes // sd.shape[0])
    return {"hbm": hbm, "host": host, "transient": transient if spec else 0}
