"""Version-compatibility shims for the jax API surface this repo targets.

The codebase is written against the explicit-sharding era jax API:
``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``. Older
jaxlib builds (<= 0.4.x) predate both. ``ensure_jax_compat()`` installs
lightweight forwarders so every call site works unchanged on either version:

  * ``jax.sharding.AxisType`` — a stand-in enum when missing (the values are
    only ever passed back into ``make_mesh``, never inspected);
  * ``jax.make_mesh`` — wrapped to accept-and-drop ``axis_types`` when the
    underlying implementation does not know the kwarg (pre-explicit-sharding
    meshes are Auto on every axis, which is exactly what the repo requests).

Idempotent and cheap; called from ``repro.dist`` import and the test
conftest so any entry point that builds a mesh is covered.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def ensure_jax_compat() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # follow_wrapped=False: functools.wraps sets __wrapped__, and a followed
    # signature would never show the shim's added kwarg — breaking idempotency
    sig = inspect.signature(jax.make_mesh, follow_wrapped=False)
    if "axis_types" not in sig.parameters:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
            del axis_types  # pre-explicit-sharding meshes are Auto everywhere
            return orig(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh


@functools.lru_cache(maxsize=None)
def _barrier_is_differentiable() -> bool:
    try:
        jax.grad(lambda x: jax.lax.optimization_barrier((x,))[0])(1.0)
        return True
    except NotImplementedError:
        return False


@jax.custom_vjp
def _barrier(tree):
    return jax.lax.optimization_barrier(tree)


def _barrier_fwd(tree):
    return jax.lax.optimization_barrier(tree), None


def _barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


def optimization_barrier(tree):
    """``jax.lax.optimization_barrier`` that is differentiable everywhere.

    Older jax releases ship the primitive without an AD rule; the barrier is
    semantically an identity, so a custom-vjp wrapper (barrier on the
    cotangents too, matching the newer built-in rule) restores gradients.
    """
    if _barrier_is_differentiable():
        return jax.lax.optimization_barrier(tree)
    return _barrier(tree)


try:  # moved to jax.shard_map in newer releases
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]


def shard_map(f, mesh, in_specs, out_specs, check: bool = True):
    """``shard_map`` across jax versions.

    ``check`` maps to the replication-checker flag, which jax has renamed
    (``check_rep`` -> ``check_vma``); callers that emit gather-based
    all-reduces (dist/collectives.manual_*) pass False because the checker
    cannot see that all_gather + identical local math yields replicated
    outputs.
    """
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check)
    except TypeError:  # pragma: no cover - newer jax renamed the flag
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=check)


@functools.lru_cache(maxsize=None)
def pallas_supported() -> bool:
    """Can this process run the Pallas kernels at all?

    True when ``jax.experimental.pallas`` imports and either the backend
    compiles Pallas natively (TPU/GPU) or interpret mode can execute the
    kernel bodies op-by-op (the CPU fallback our tests use — bit-identical
    math, no Mosaic). False on builds without Pallas, in which case the
    ``repro.kernels`` package routes every request to the pure-jnp reference
    implementations instead of crashing."""
    try:
        import jax.experimental.pallas as pl  # noqa: F401
    except Exception:  # pragma: no cover - jaxlib built without pallas
        return False
    return True


@functools.lru_cache(maxsize=None)
def pallas_interpret_required() -> bool:
    """True when Pallas must run in interpret mode (no kernel compiler for
    this backend — i.e. anything but TPU/GPU)."""
    try:
        return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
    except Exception:  # pragma: no cover - backend init can fail headless
        return True


def host_memory_kind(mesh) -> str | None:
    """The best host-side memory kind the mesh's devices support.

    TPU/GPU expose ``pinned_host``; the CPU backend only ``unpinned_host``
    (which still exercises every placement/fetch code path in tests). Returns
    None when the platform has no addressable host memory space at all, in
    which case host placement degrades to device residence.
    """
    try:
        kinds = {m.kind for m in mesh.devices.flat[0].addressable_memories()}
    except Exception:
        return None
    for kind in ("pinned_host", "unpinned_host"):
        if kind in kinds:
            return kind
    return None
