"""Fused mixed-precision Adam update as a Pallas TPU kernel.

One pass over HBM per state tensor (read p/g/master/m/v, write p/master/m/v)
instead of the ~10 reads/writes an unfused elementwise chain costs — the
optimizer phase is pure HBM bandwidth, so fusion is the whole win (the paper's
FusedAdam/CPU-Adam analogue for the TPU memory hierarchy).

Inputs are flattened and padded to (rows, 1024) tiles; scalars (lr and the
bias corrections, which change per step) arrive as (1,1) operands so the
kernel never recompiles across steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024  # 8 sublanes x 128 lanes


def _adam_kernel(
    scal_ref,  # (1, 8) f32: [lr, b1, b2, eps, wd, bc1, bc2, _]
    p_ref, g_ref, ma_ref, m_ref, v_ref,
    p_out, ma_out, m_out, v_out,
):
    lr = scal_ref[0, 0]
    b1 = scal_ref[0, 1]
    b2 = scal_ref[0, 2]
    eps = scal_ref[0, 3]
    wd = scal_ref[0, 4]
    bc1 = scal_ref[0, 5]
    bc2 = scal_ref[0, 6]
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    upd = upd + wd * ma_ref[...]
    ma_new = ma_ref[...] - lr * upd
    p_out[...] = ma_new.astype(p_out.dtype)
    ma_out[...] = ma_new
    m_out[...] = m_new
    v_out[...] = v_new


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_adam(
    p: jax.Array,
    g: jax.Array,
    master: jax.Array,
    m: jax.Array,
    v: jax.Array,
    scalars: jax.Array,  # (8,) f32: [lr, b1, b2, eps, wd, bc1, bc2, 0]
    *,
    block_rows: int = 256,
    interpret: bool = False,
):
    """Returns (p_new, master_new, m_new, v_new); any-shape inputs."""
    shape, dtype = p.shape, p.dtype
    n = p.size
    rows = (n + LANE - 1) // LANE
    rows_p = (rows + block_rows - 1) // block_rows * block_rows
    pad = rows_p * LANE - n

    def prep(x, dt):
        return jnp.pad(x.reshape(-1).astype(dt), (0, pad)).reshape(rows_p, LANE)

    args = (
        prep(p, dtype), prep(g, g.dtype), prep(master, jnp.float32),
        prep(m, jnp.float32), prep(v, jnp.float32),
    )
    grid = (rows_p // block_rows,)
    blk = lambda: pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    scal = scalars.reshape(1, 8).astype(jnp.float32)
    outs = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0))] + [blk() for _ in range(5)],
        out_specs=[blk() for _ in range(4)],
        out_shape=[
            jax.ShapeDtypeStruct((rows_p, LANE), dtype),
            jax.ShapeDtypeStruct((rows_p, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows_p, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows_p, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(scal, *args)

    def unprep(x, dt):
        return x.reshape(-1)[:n].reshape(shape).astype(dt)

    return (
        unprep(outs[0], dtype), unprep(outs[1], jnp.float32),
        unprep(outs[2], jnp.float32), unprep(outs[3], jnp.float32),
    )
