"""FlashAttention forward as a Pallas TPU kernel.

TPU adaptation notes (vs. the CUDA original): tiling is chosen for VMEM and
the MXU — the (block_q x hd) @ (hd x block_k) products keep every matmul dim a
multiple of 128 (MXU-aligned for hd >= 128; zero-padded otherwise by Mosaic),
online-softmax statistics live in fp32 VMEM scratch across the arbitrary-
ordered KV grid dimension, and fully-masked KV tiles are skipped via the grid
rather than warp-level early exit. GQA is handled in the index maps (a KV
head is revisited by ``group`` consecutive Q heads) so K/V tiles are fetched
once per group from HBM.

Grid: (batch*heads, Sq/block_q, Sk/block_k) with
dimension_semantics=(parallel, parallel, arbitrary) — the KV axis is the
sequential accumulation axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    acc_ref, m_ref, l_ref,  # VMEM scratch
    *,
    scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int,
    sk: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (block_q, hd)
    k = k_ref[0]  # (block_k, hd)
    v = v_ref[0]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]  # (block_q, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == nk - 1)
    def finalize():
        o_ref[0, ...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = 1.0 / np.sqrt(hd)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # pad sequence dims to block multiples (masked out by `kpos < sk`)
    sq_p = (sq + block_q - 1) // block_q * block_q
    sk_p = (sk + block_k - 1) // block_k * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    qf = q.reshape(b * hq, sq_p, hd)
    kf = k.reshape(b * hkv, sk_p, hd)
    vf = v.reshape(b * hkv, sk_p, hd)
    grid = (b * hq, sq_p // block_q, sk_p // block_k)

    def q_index(h, i, j):
        return (h, i, 0)

    def kv_index(h, i, j):
        # GQA: query head h belongs to kv head (h % hq) // group of batch h // hq
        bidx = h // hq
        kvh = (h % hq) // group
        return (bidx * hkv + kvh, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
            causal=causal, window=window, sk=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),  # l (running denom)
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq_p, hd)[:, :, :sq]
