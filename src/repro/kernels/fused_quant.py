"""Fused int8 absmax quantize + pack + error-feedback update (Pallas).

The manual-sync wire path (dist/collectives.manual_int8_ef_reduce_scatter)
used to run three separate passes over the fp32 chunk view before the
all_to_all: an abs/max reduction for the per-chunk scale, a divide/round/clip
pass producing the s8 payload, and a dequant-subtract pass for the new EF
residual of the owned chunk. This kernel fuses them: one streamed pass per
chunk emits the s8 payload, its fp32 scale, and — on the grid step whose
chunk this device owns — the updated residual.

Grid is ``(z,)`` (one step per sync peer's chunk, ``arbitrary`` ordering);
each step holds one flattened (1, N) chunk block in VMEM. The owner index
``me`` rides in SMEM so the residual write can be predicated per step —
under ``shard_map`` it is ``lax.axis_index``, a traced per-device scalar.

Exactness: every op is the same elementwise/ exact-reduction op the three-op
sequence ran — ``max(|x|)`` is order-independent, divide/round(half-even)/
clip are elementwise — so payload, scales, and residual are bit-identical to
the unfused path (tests/test_paged_attention_kernel.py property-tests this
under hypothesis). The collective itself (all_to_all of s8 + scales) stays
outside: Pallas kernels cannot contain collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# CompilerParams was renamed across jax releases (same fields)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(me_ref, ch_ref, q_ref, scale_ref, err_ref):
    i = pl.program_id(0)
    ch = ch_ref[0]
    # same op sequence as the three-op path: absmax (exact reduction),
    # clamp, /127, round half-even, clip, s8 cast, dequant-subtract
    scale = jnp.maximum(jnp.max(jnp.abs(ch)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(ch / scale), -127, 127).astype(jnp.int8)
    q_ref[0] = q
    scale_ref[0, 0] = scale

    @pl.when(i == me_ref[0])
    def _own_residual():
        err_ref[0] = ch - q.astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_quantize_ef(
    ch: jax.Array,  # (z, *shard) fp32 chunked tensor, EF already added at [me]
    me: jax.Array,  # () int32 — this device's chunk index (lax.axis_index)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-pass absmax int8 quantize of ``z`` chunks.

    Returns ``(q, scales, new_err)``: s8 payload shaped like ``ch``, (z,)
    fp32 per-chunk scales, and the owned chunk's fp32 EF residual shaped
    like ``ch[0]`` — bit-identical to the three-op sequence.
    """
    z = ch.shape[0]
    shard_shape = ch.shape[1:]
    n = 1
    for d in shard_shape:
        n *= d
    flat = ch.astype(jnp.float32).reshape(z, n)
    q, scale, err = pl.pallas_call(
        _kernel,
        grid=(z,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((z, n), jnp.int8),
            jax.ShapeDtypeStruct((z, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray(me, jnp.int32).reshape(1), flat)
    return (q.reshape(ch.shape), scale[:, 0],
            err[0].reshape(shard_shape))
