"""Fused RMSNorm as a Pallas TPU kernel: one HBM pass computing fp32 row
statistics and the scaled output (vs. separate reduce + normalize + scale
kernels). Rows tile over the grid; the full feature dim stays resident in
VMEM (d_model * 4B — up to ~18k features fits comfortably in 64 MB VMEM
alongside double buffering)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)).astype(o_ref.dtype) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(
    x: jax.Array,  # (..., D)
    scale: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    rows_p = (rows + block_rows - 1) // block_rows * block_rows
    if rows_p != rows:
        xf = jnp.pad(xf, ((0, rows_p - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows_p // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        interpret=interpret,
    )(xf, scale.reshape(1, d))
    return out[:rows].reshape(shape)
