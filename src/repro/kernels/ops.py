"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they
run in interpret mode, which executes the kernel body op-by-op — bit-for-bit
the same math, so tests validate the kernel logic against the ref.py oracles
without TPU hardware.

The interpret decision is resolved once per process (``interpret_mode``):
it depends only on the backend, which jax fixes at first use, so consulting
``compat.pallas_interpret_required`` on every kernel call was pure overhead.
``assert_ref_agreement`` is the one shared kernel-vs-oracle structure
checker (dtype + shape over arbitrary output pytrees) used by the kernel
tests and ``benchmarks/kernel_bench.py`` — per-op copies of the same
asserts are gone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import pallas_interpret_required
from repro.kernels import flash_attention as _flash
from repro.kernels import fused_adam as _fa
from repro.kernels import fused_quant as _fq
from repro.kernels import paged_attention as _pa
from repro.kernels import rmsnorm as _rn

_INTERPRET: bool | None = None


def interpret_mode() -> bool:
    """Process-wide interpret decision, resolved on first kernel call.

    Interpret mode covers every backend without a Pallas compiler (CPU CI
    included); the capability probe lives in repro.compat.
    """
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = pallas_interpret_required()
    return _INTERPRET


def assert_ref_agreement(kernel_out, ref_out) -> None:
    """Assert kernel and oracle outputs agree structurally (dtype + shape).

    One checker for every op: outputs may be a single array or any pytree
    of arrays (the fused quantizer returns a triple). Value comparison is
    the caller's job — tolerance is per-op, structure is not.
    """
    k_leaves, k_def = jax.tree.flatten(kernel_out)
    r_leaves, r_def = jax.tree.flatten(ref_out)
    assert k_def == r_def, f"kernel/ref structure mismatch: {k_def} vs {r_def}"
    for kl, rl in zip(k_leaves, r_leaves):
        assert kl.shape == rl.shape, f"shape mismatch: {kl.shape} vs {rl.shape}"
        assert kl.dtype == rl.dtype, f"dtype mismatch: {kl.dtype} vs {rl.dtype}"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret_mode(),
    )


def fused_adam_update(p, g, master, m, v, *, lr, b1, b2, eps, weight_decay, bc1, bc2):
    """Signature-compatible with optim.adam._update_leaf's fused branch."""
    scal = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(b1, jnp.float32),
        jnp.asarray(b2, jnp.float32), jnp.asarray(eps, jnp.float32),
        jnp.asarray(weight_decay, jnp.float32), jnp.asarray(bc1, jnp.float32),
        jnp.asarray(bc2, jnp.float32), jnp.zeros((), jnp.float32),
    ])
    return _fa.fused_adam(p, g, master, m, v, scal, interpret=interpret_mode())


def rmsnorm(x, scale, *, eps: float = 1e-6):
    return _rn.rmsnorm(x, scale, eps=eps, interpret=interpret_mode())


def decode_paged_attention(q, k_hot, v_hot, k_cold, v_cold, sel, mask, *, n_hot):
    """Fused single-token decode attention over the paged cache layout
    (serve/paging.PagedKV) — bit-identical to the lax gather-then-attend
    path; see kernels/paged_attention.py for the block layout."""
    return _pa.paged_attention(q, k_hot, v_hot, k_cold, v_cold, sel, mask,
                               n_hot=n_hot, interpret=interpret_mode())


def fused_quantize_ef(ch, me):
    """One-pass int8 absmax quantize + pack + EF residual update for the
    manual-sync wire path (dist/collectives) — bit-identical to the three-op
    sequence it replaces; see kernels/fused_quant.py."""
    return _fq.fused_quantize_ef(ch, me, interpret=interpret_mode())
