"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they
run in interpret mode, which executes the kernel body op-by-op — bit-for-bit
the same math, so tests validate the kernel logic against the ref.py oracles
without TPU hardware.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.compat import pallas_interpret_required
from repro.kernels import fused_adam as _fa
from repro.kernels import flash_attention as _flash
from repro.kernels import rmsnorm as _rn


def _interpret() -> bool:
    # capability probe lives in repro.compat; interpret mode covers every
    # backend without a Pallas compiler (CPU CI included)
    return pallas_interpret_required()


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_interpret(),
    )


def fused_adam_update(p, g, master, m, v, *, lr, b1, b2, eps, weight_decay, bc1, bc2):
    """Signature-compatible with optim.adam._update_leaf's fused branch."""
    scal = jnp.stack([
        jnp.asarray(lr, jnp.float32), jnp.asarray(b1, jnp.float32),
        jnp.asarray(b2, jnp.float32), jnp.asarray(eps, jnp.float32),
        jnp.asarray(weight_decay, jnp.float32), jnp.asarray(bc1, jnp.float32),
        jnp.asarray(bc2, jnp.float32), jnp.zeros((), jnp.float32),
    ])
    return _fa.fused_adam(p, g, master, m, v, scal, interpret=_interpret())


def rmsnorm(x, scale, *, eps: float = 1e-6):
    return _rn.rmsnorm(x, scale, eps=eps, interpret=_interpret())
