"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(
    q: jax.Array,  # (B, Hq, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,  # (B, Hkv, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qh = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32) / np.sqrt(hd)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qh, k.astype(jnp.float32))
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


def fused_adam_ref(p, g, master, m, v, *, lr, b1, b2, eps, weight_decay, bc1, bc2):
    gf = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * gf
    v_new = b2 * v + (1 - b2) * gf * gf
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if weight_decay:
        upd = upd + weight_decay * master
    master_new = master - lr * upd
    return master_new.astype(p.dtype), master_new, m_new, v_new


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype) * scale
