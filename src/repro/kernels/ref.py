"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(
    q: jax.Array,  # (B, Hq, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,  # (B, Hkv, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qh = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32) / np.sqrt(hd)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qh, k.astype(jnp.float32))
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


def fused_adam_ref(p, g, master, m, v, *, lr, b1, b2, eps, weight_decay, bc1, bc2):
    gf = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * gf
    v_new = b2 * v + (1 - b2) * gf * gf
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if weight_decay:
        upd = upd + weight_decay * master
    master_new = master - lr * upd
    return master_new.astype(p.dtype), master_new, m_new, v_new


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype) * scale


def paged_attention_ref(q, k_hot, v_hot, k_cold, v_cold, sel, mask):
    """Oracle for kernels/paged_attention.py: materialize the ring view
    (cache row ``r`` lives at ring row ``r % hot_window``), select the
    canonical rows, then run ``_masked_decode_attn``'s exact op sequence.

    q: (B, 1, Hq, hd); k/v_hot: (B, W, Hkv, hd); k/v_cold: (B, S, Hkv, hd);
    sel: (B, S) bool (True -> ring canonical); mask: (B, S) fp32 additive.
    """
    b, _, hq, hd = q.shape
    s_kv, hkv = k_cold.shape[1], k_cold.shape[2]
    w = k_hot.shape[1]
    g = hq // hkv
    rows = jnp.arange(s_kv) % w
    s = sel[..., None, None]
    k = jnp.where(s, jnp.take(k_hot, rows, axis=1), k_cold)
    v = jnp.where(s, jnp.take(v_hot, rows, axis=1), v_cold)
    qh = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))).reshape(b, hkv, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qh, k.astype(jnp.float32))
    logits = logits + mask[:, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def fused_quantize_ef_ref(ch, me):
    """Oracle for kernels/fused_quant.py: the three-op sequence of
    dist/collectives.manual_int8_ef_reduce_scatter, verbatim.

    ch: (z, *shard) fp32 (EF residual already added at chunk ``me``).
    Returns (q s8 like ch, scales (z,) fp32, new_err fp32 like ch[0]).
    """
    ch = ch.astype(jnp.float32)
    z = ch.shape[0]
    scale = jnp.maximum(
        jnp.max(jnp.abs(ch), axis=tuple(range(1, ch.ndim))), 1e-30) / 127.0
    q = jnp.clip(jnp.round(ch / scale.reshape((z,) + (1,) * (ch.ndim - 1))),
                 -127, 127).astype(jnp.int8)
    own = jnp.take(ch, me, axis=0)
    new_err = own - jnp.take(q, me, axis=0).astype(jnp.float32) * jnp.take(scale, me)
    return q, scale, new_err
