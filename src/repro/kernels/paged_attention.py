"""Pallas decode-attention kernel over the paged KV-cache layout.

The serving subsystem (repro/serve/paging.py) splits each attention layer's
(B, S, n_kv, hd) decode cache into a hot HBM ring of the last
``hot_window = page_size * n_hot`` slots plus a canonical cold store. The
plain-lax decode path reconstructs the full cache page by page (``jnp.where``
selects between ring slice and cold tile), materializes the concatenation in
HBM, and only then runs single-query attention over it — a gather-then-attend
memory round trip on every token, for every attention layer (the pre-PR-8
"rebuilds the cache in plain lax ops" known limit).

This kernel consumes the paged layout directly. The grid walks
``(B*Hkv, n_pages)``; each KV step streams one page as a pair of K/V blocks —
the hot-ring slice at ring page ``j % n_hot`` and the cold tile at page
``j`` — selects the canonical rows with the precomputed per-row residency
mask (``PagedKV`` flush semantics), and accumulates that page's attention
logits into a VMEM scratch row. The gathered cache never exists in HBM: one
streamed pass replaces the rebuild's read-write-read.

Block layout per (batch*kv-head, page) grid step::

      q        (1, G, hd)    fixed block, G = Hq // Hkv query heads
      k_hot    (1, P, hd)    ring page  j % n_hot   ─┐ per-row select
      k_cold   (1, P, hd)    cold page  j           ─┘ (sel block)
      sel,mask (1, P)        residency + additive NEG_INF decode mask
      scratch  logits (G, S) fp32, v (S, hd) fp32   accumulated across pages
      out      (1, G, hd)    written on the final page

Exactness contract (the PR-5 bitwise guarantee must survive): the decoded
logits are **bit-identical** to the lax rebuild path. Two deliberate choices
make that hold rather than merely approximate:

  * masking is additive ``NEG_INF`` exactly as ``kvcache.decode_mask``
    emits it, so a masked (stale ring) row's softmax weight underflows to
    exactly 0.0 in fp32 — residency choices on masked rows are invisible;
  * the softmax runs **once over the full streamed logits row** (decode is
    single-query, so the row fits VMEM: G x S fp32). An online-softmax
    rescaling chain (exp(x - m_j) * exp(m_j - m_{j+1}) ...) reassociates the
    reduction and drifts from ``jax.nn.softmax`` by ulps, which would break
    the bitwise parity tests; with the row resident, max / exp / sum /
    divide / PV-dot are the exact op sequence of ``_masked_decode_attn``.
    Multi-query prefill, where rows do not fit, keeps the flash-style
    online pass in ``kernels/flash_attention.py``.

VMEM bound: logits (G, S) + gathered V (S, hd) fp32 — ~2.2 MB for G=16,
S=32k, hd=128-ary V at S=4k; long-context decode needs a KV-split grid
(follow-up, priced by the cost model's ``paged_attn`` calibration key).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# CompilerParams was renamed across jax releases (same fields)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(q_ref, kh_ref, kc_ref, vh_ref, vc_ref, sel_ref, mask_ref,
            o_ref, logits_ref, v_ref, *, n_pages: int, hd: int):
    j = pl.program_id(1)
    psz = kh_ref.shape[1]
    # per-row residency select: True -> hot ring holds the canonical value
    sel = sel_ref[0][:, None]
    k = jnp.where(sel, kh_ref[0], kc_ref[0]).astype(jnp.float32)
    v = jnp.where(sel, vh_ref[0], vc_ref[0]).astype(jnp.float32)
    # same scaling op sequence as _masked_decode_attn: fp32 cast, / sqrt(hd)
    qf = q_ref[0].astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
    logits = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits_ref[:, pl.ds(j * psz, psz)] = logits + mask_ref[0][None, :]
    v_ref[pl.ds(j * psz, psz), :] = v

    @pl.when(j == n_pages - 1)
    def _finalize():
        full = logits_ref[...]
        m = jnp.max(full, axis=-1, keepdims=True)
        p = jnp.exp(full - m)
        probs = p / jnp.sum(p, axis=-1, keepdims=True)
        out = jax.lax.dot_general(probs, v_ref[...], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_hot", "interpret"))
def paged_attention(
    q: jax.Array,       # (B, 1, Hq, hd) post-RoPE query, model dtype
    k_hot: jax.Array,   # (B, W, Hkv, hd) hot ring, W = page_size * n_hot
    v_hot: jax.Array,   # (B, W, Hkv, hd)
    k_cold: jax.Array,  # (B, S, Hkv, hd) canonical cold store
    v_cold: jax.Array,  # (B, S, Hkv, hd)
    sel: jax.Array,     # (B, S) bool — True where the ring row is canonical
    mask: jax.Array,    # (B, S) fp32 additive decode mask (0 / NEG_INF)
    *,
    n_hot: int,
    interpret: bool = False,
) -> jax.Array:
    """Single-token decode attention over hot ring + cold pages.

    Returns (B, 1, Hq, hd) in q's dtype — bit-identical to
    ``_masked_decode_attn(q, gather(k), gather(v), mask)`` where ``gather``
    is ``PagedKV._gather``'s page-wise reconstruction.
    """
    b, _, hq, hd = q.shape
    s_kv, hkv = k_cold.shape[1], k_cold.shape[2]
    w = k_hot.shape[1]
    assert w % n_hot == 0, (w, n_hot)
    psz = w // n_hot
    assert s_kv % psz == 0, (s_kv, psz)
    n_pages = s_kv // psz
    g = hq // hkv

    # fold (B, Hkv) into one grid axis; move heads ahead of the slot axis
    qf = q.reshape(b, hkv, g, hd).reshape(b * hkv, g, hd)

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * hkv, x.shape[1], hd)

    grid = (b * hkv, n_pages)
    out = pl.pallas_call(
        functools.partial(_kernel, n_pages=n_pages, hd=hd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, psz, hd), lambda h, j: (h, j % n_hot, 0)),
            pl.BlockSpec((1, psz, hd), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, psz, hd), lambda h, j: (h, j % n_hot, 0)),
            pl.BlockSpec((1, psz, hd), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, psz), lambda h, j: (h // hkv, j)),
            pl.BlockSpec((1, psz), lambda h, j: (h // hkv, j)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, s_kv), jnp.float32),
            pltpu.VMEM((s_kv, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, fold(k_hot), fold(k_cold), fold(v_hot), fold(v_cold), sel, mask)
    return out.reshape(b, hkv, g, hd).reshape(b, 1, hq, hd)
