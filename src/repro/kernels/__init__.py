"""Capability-gated kernel dispatch (ROADMAP: "wire the Pallas paths into
the step builder behind a capability check").

Consumers request fused ops from *this package* — never from ``ops.py``
directly — so the request is always safe:

  * ``repro.compat.pallas_supported()`` — Pallas imports and can execute
    (compiled on TPU/GPU, interpret mode elsewhere; ``ops.py`` picks via
    ``compat.pallas_interpret_required``): route to the Pallas wrappers;
  * otherwise (jaxlib built without Pallas): route to the pure-jnp oracles
    in ``ref.py``, which are the allclose targets the kernels are tested
    against — same math, no crash.

``optim/adam.py`` reaches its fused update through here, which is what lets
``AdamConfig(use_fused_kernel=True)`` run on CPU CI (interpret mode) and on
kernel-less builds (reference path) without special-casing the step builder.

``fused_adam_update``, ``decode_paged_attention``, and ``fused_quantize_ef``
are re-exported at package level: their names do not collide with a
submodule. ``flash_attention`` / ``rmsnorm`` keep their submodule import
paths (``repro.kernels.ops`` applies the same capability gating) — binding
same-named functions on the package would shadow the
``repro.kernels.flash_attention`` / ``repro.kernels.rmsnorm`` modules for
``import … as`` style imports (which is also why the decode kernel exports
as ``decode_paged_attention``, not ``paged_attention``).

``pallas_kernels_active()`` is the capability probe call sites gate *path
selection* on (serve/paging.PagedKV's kernel-vs-lax split, the collectives'
fused-vs-three-op quantize, cost-model pricing): True means the package
routes to real Pallas wrappers rather than the ref fallbacks.
"""
from __future__ import annotations

from repro.compat import pallas_supported


def pallas_kernels_active() -> bool:
    """True when this package dispatches to Pallas kernels (compiled or
    interpret), False when it routes to the ref.py oracles."""
    return pallas_supported()


if pallas_supported():
    from repro.kernels.ops import (  # noqa: F401
        decode_paged_attention,
        fused_adam_update,
        fused_quantize_ef,
    )
else:  # pragma: no cover - exercised only on pallas-less jaxlib builds

    def fused_adam_update(p, g, master, m, v, *, lr, b1, b2, eps,
                          weight_decay, bc1, bc2):
        """Signature-compatible reference fallback (see optim/adam.py)."""
        from repro.kernels.ref import fused_adam_ref

        return fused_adam_ref(p, g, master, m, v, lr=lr, b1=b1, b2=b2,
                              eps=eps, weight_decay=weight_decay,
                              bc1=bc1, bc2=bc2)

    def decode_paged_attention(q, k_hot, v_hot, k_cold, v_cold, sel, mask,
                               *, n_hot):
        """Signature-compatible reference fallback (see serve/paging.py)."""
        from repro.kernels.ref import paged_attention_ref

        return paged_attention_ref(q, k_hot, v_hot, k_cold, v_cold, sel, mask)

    def fused_quantize_ef(ch, me):
        """Signature-compatible reference fallback (see dist/collectives.py)."""
        from repro.kernels.ref import fused_quantize_ef_ref

        return fused_quantize_ef_ref(ch, me)
