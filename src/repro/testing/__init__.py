"""Test-support utilities (hypothesis fallback shim, shared helpers)."""
