"""Minimal deterministic stand-in for the ``hypothesis`` API the suite uses.

The property suites (tests/test_core.py, tests/test_properties.py) depend on
hypothesis, which is a declared test dependency (pyproject ``[test]``) but not
part of the hermetic CI/container image. Rather than skip ~10 invariant tests
when it is absent, ``install()`` registers this module as ``hypothesis`` in
``sys.modules`` so the same test code runs against a small, seeded,
reproducible random-example engine.

Scope: exactly the surface the suite imports — ``given``, ``settings``,
``assume`` and ``strategies.{integers, lists, sampled_from, text, floats,
booleans, just, tuples, data}``. Draws are seeded per test name, so failures
reproduce across runs; the first example of every integer strategy pins the
lower bound and the second the upper, so boundary cases are always exercised.
This is NOT a shrinking property-based engine; with real hypothesis installed
it is never imported.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

__version__ = "0.stub"
_DEFAULT_MAX_EXAMPLES = 100


class _Unsatisfied(Exception):
    """Raised by assume(False); the example is silently discarded."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class SearchStrategy:
    def __init__(self, draw, name="strategy"):
        self._draw = draw
        self._name = name

    def example_from(self, rng: random.Random, index: int = 0):
        return self._draw(rng, index)

    def map(self, f):
        return SearchStrategy(lambda rng, i: f(self._draw(rng, i)), f"{self._name}.map")

    def filter(self, pred):
        def draw(rng, i):
            for _ in range(100):
                v = self._draw(rng, i)
                if pred(v):
                    return v
                i = -1  # boundary example failed the predicate: go random
            raise _Unsatisfied()

        return SearchStrategy(draw, f"{self._name}.filter")

    def __repr__(self):
        return self._name


class DataObject:
    """The object ``st.data()`` hands to the test for interactive draws."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label=None):
        return strategy.example_from(self._rng, -1)


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda rng, i: DataObject(rng), "data()")


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2**31) if min_value is None else min_value
    hi = 2**31 if max_value is None else max_value

    def draw(rng, i):
        if i == 0:
            return lo
        if i == 1:
            return hi
        return rng.randint(lo, hi)

    return SearchStrategy(draw, f"integers({lo}, {hi})")


def floats(min_value=0.0, max_value=1.0, **_kw) -> SearchStrategy:
    return SearchStrategy(
        lambda rng, i: rng.uniform(min_value, max_value), "floats"
    )


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng, i: rng.random() < 0.5, "booleans")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng, i: value, f"just({value!r})")


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng, i: rng.choice(elements), "sampled_from")


def lists(elements: SearchStrategy, min_size=0, max_size=None, **_kw) -> SearchStrategy:
    hi = (min_size + 20) if max_size is None else max_size

    def draw(rng, i):
        n = min_size if i == 0 else rng.randint(min_size, hi)
        return [elements.example_from(rng, -1) for _ in range(n)]

    return SearchStrategy(draw, "lists")


def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=None) -> SearchStrategy:
    chars = list(alphabet) if not isinstance(alphabet, SearchStrategy) else None
    hi = (min_size + 40) if max_size is None else max_size

    def draw(rng, i):
        n = min_size if i == 0 else rng.randint(min_size, hi)
        if chars is None:
            return "".join(alphabet.example_from(rng, -1) for _ in range(n))
        return "".join(rng.choice(chars) for _ in range(n))

    return SearchStrategy(draw, "text")


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng, i: tuple(s.example_from(rng, i) for s in strategies),
        "tuples",
    )


def data() -> SearchStrategy:
    return _DataStrategy()


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        target = {"max_examples": max_examples or _DEFAULT_MAX_EXAMPLES}
        fn._stub_settings = target
        return fn

    return deco


def given(*pos_strategies, **kw_strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # positional strategies map to the test's trailing parameters,
        # matching hypothesis' right-aligned convention
        strat_map = dict(zip(names[len(names) - len(pos_strategies):], pos_strategies))
        strat_map.update(kw_strategies)
        fixture_params = [p for n, p in sig.parameters.items() if n not in strat_map]
        seed0 = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", {"max_examples": _DEFAULT_MAX_EXAMPLES}
            )
            for i in range(cfg["max_examples"]):
                rng = random.Random((seed0 + i * 7919) & 0xFFFFFFFF)
                try:
                    drawn = {k: s.example_from(rng, i) for k, s in strat_map.items()}
                    fn(*args, **{**kwargs, **drawn})
                except _Unsatisfied:
                    continue

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        wrapper.is_hypothesis_test = True
        return wrapper

    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


def note(message):  # pragma: no cover - debugging aid only
    print(message)


def install() -> types.ModuleType:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    this = sys.modules[__name__]
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "lists", "text", "tuples", "data"):
        setattr(strategies, name, getattr(this, name))
    strategies.SearchStrategy = SearchStrategy
    this.strategies = strategies
    sys.modules["hypothesis"] = this
    sys.modules["hypothesis.strategies"] = strategies
    return this
