"""Mamba-2 (SSD — state-space duality) mixer [arXiv:2405.21060].

Chunked SSD: quadratic attention-like compute within chunks of length Q and a
linear ``lax.scan`` recurrence across chunks — O(S·Q) work, O(S) memory, which
is what makes the ``long_500k`` shape tractable. Single-step recurrence for
decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import NONE, TP, ZERO, ParamDef, rmsnorm


def mamba2_dims(cfg):
    mc = cfg.mamba2
    d_in = mc.expand * cfg.d_model
    n_heads = d_in // mc.head_dim
    conv_dim = d_in + 2 * mc.d_state
    return d_in, n_heads, conv_dim


def mamba2_defs(cfg) -> dict:
    mc = cfg.mamba2
    d = cfg.d_model
    d_in, n_heads, conv_dim = mamba2_dims(cfg)
    proj_out = 2 * d_in + 2 * mc.d_state + n_heads  # [z, x, B, C, dt]
    return {
        "in_proj": ParamDef((d, proj_out), (ZERO, TP)),
        "conv_w": ParamDef((mc.d_conv, conv_dim), (NONE, TP), scale=0.1),
        "conv_b": ParamDef((conv_dim,), (TP,), init="zeros"),
        "A_log": ParamDef((n_heads,), (TP,), init="ones", dtype="float32"),
        "D": ParamDef((n_heads,), (TP,), init="ones", dtype="float32"),
        "dt_bias": ParamDef((n_heads,), (TP,), init="zeros", dtype="float32"),
        "norm_scale": ParamDef((d_in,), (TP,), init="ones"),
        "out_proj": ParamDef((d_in, d), (TP, ZERO)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C). Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    new_state = xp[:, -(k - 1) :, :] if k > 1 else state
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(y + b), new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> (..., Q, Q) with out[i,j] = sum(a[j+1..i]), -inf above diag."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B,S,H,P) inputs (dt already folded in by caller? no — raw)
    dt: jax.Array,  # (B,S,H) positive step sizes
    a: jax.Array,  # (H,) negative decay rates (A = -exp(A_log))
    b_mat: jax.Array,  # (B,S,N)
    c_mat: jax.Array,  # (B,S,N)
    chunk_size: int,
    initial_state: jax.Array | None = None,
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk_size, s)
    if s % q:
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // q

    xdt = (x * dt[..., None]).astype(jnp.float32)  # dt-scaled input
    adt = (a[None, None, :] * dt).astype(jnp.float32)  # (B,S,H) log-decay per step
    # chunked views
    xc = xdt.reshape(bsz, nc, q, h, p)
    ac = adt.reshape(bsz, nc, q, h)
    bc = b_mat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, nc, q, n).astype(jnp.float32)

    a_cs = jnp.cumsum(ac, axis=2)  # (B,nc,Q,H)
    # 1) intra-chunk (quadratic within chunk)
    l_mat = jnp.exp(_segsum(jnp.moveaxis(ac, -1, 2)))  # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp", cc, bc, l_mat, xc)
    # 2) per-chunk end states
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, decay_states, xc)
    # 3) inter-chunk recurrence (linear scan over chunks)
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])  # (B,nc,H)
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def body(carry, inp):
        st, dec = inp  # st: (B,H,P,N), dec: (B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        body,
        initial_state.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N)
    # 4) state -> output within chunk
    state_decay = jnp.exp(a_cs)  # (B,nc,Q,H)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(bsz, sp, h, p)[:, :s]
    return y.astype(x.dtype), final_state


def apply_mamba2(
    params: dict,
    x: jax.Array,
    cfg,
    *,
    state: tuple[jax.Array, jax.Array] | None = None,
    return_state: bool = False,
):
    """x: (B,S,D) -> (B,S,D). ``state`` = (conv_state, ssm_state) for decode."""
    mc = cfg.mamba2
    d_in, n_heads, conv_dim = mamba2_dims(cfg)
    b, s, _ = x.shape
    proj = x @ params["in_proj"]
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + mc.d_state, 2 * d_in + 2 * mc.d_state], axis=-1
    )
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_state = state[0] if state is not None else None
    conv_out, new_conv_state = _causal_conv(conv_in, params["conv_w"], params["conv_b"], conv_state)
    xin, bmat, cmat = jnp.split(conv_out, [d_in, d_in + mc.d_state], axis=-1)
    xh = xin.reshape(b, s, n_heads, mc.head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])  # (H,)
    ssm_state = state[1] if state is not None else None
    y, new_ssm_state = ssd_chunked(xh, dtp, a, bmat, cmat, mc.chunk_size, ssm_state)
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    out = y @ params["out_proj"]
    if return_state:
        return out, (new_conv_state, new_ssm_state)
    return out


def mamba2_state_defs(cfg, batch: int):
    """ShapeDtype templates for the decode state cache."""
    mc = cfg.mamba2
    d_in, n_heads, conv_dim = mamba2_dims(cfg)
    return (
        jax.ShapeDtypeStruct((batch, mc.d_conv - 1, conv_dim), jnp.dtype(cfg.dtype)),
        jax.ShapeDtypeStruct((batch, n_heads, mc.head_dim, mc.d_state), jnp.float32),
    )
