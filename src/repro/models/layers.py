"""Core transformer layers: norms, RoPE, attention (naive + blockwise), MLPs.

Everything is functional: ``params`` are pytrees of jnp arrays, layers are pure
functions. Parameter *definitions* (shape + init + sharding axis tags) live
next to the apply functions so model assembly stays in one place.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Sharding axis tags. dist/sharding.py maps these to mesh axes given a plan.
# ---------------------------------------------------------------------------
LAYER = "layer"  # stacked-layer leading axis (scanned over, never sharded)
ZERO = "zero"  # ZeRO-shardable dim (sharded over (pod, data) when non-persistent)
TP = "tp"  # tensor-parallel dim (sharded over model axis)
EXP = "exp"  # expert dim (expert-parallel over model axis)
NONE = "none"  # never sharded


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; None -> 1/sqrt(fan_in)
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def initialize(self, key: jax.Array) -> jax.Array:
        dt = jnp.dtype(self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dt)


def init_tree(defs, key: jax.Array):
    """Initialize a pytree of ParamDefs into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [d.initialize(k) for d, k in zip(leaves, keys)])


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # Statistics accumulate in fp32 via preferred_element_type without ever
    # materializing an fp32 copy of x — a bare convert as the first op of a
    # rematerialized block gets hoisted out of the backward loop by XLA and
    # stacks an fp32 copy of every saved boundary (2x activation memory).
    d = x.shape[-1]
    ms = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32) / d
    rs = jax.lax.rsqrt(ms + eps)[..., None].astype(x.dtype)
    return x * rs * scale


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6):
    d = x.shape[-1]
    ones = jnp.ones((d,), x.dtype)
    mu = (jnp.einsum("...d,d->...", x, ones, preferred_element_type=jnp.float32) / d)
    ms = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32) / d
    var = ms - mu * mu
    rs = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    mu = mu[..., None].astype(x.dtype)
    return (x - mu) * rs * scale + bias


def norm_defs(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), (NONE,), init="ones")}
    return {
        "scale": ParamDef((d,), (NONE,), init="ones"),
        "bias": ParamDef((d,), (NONE,), init="zeros"),
    }


def apply_norm(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (hd/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
) -> jax.Array:
    """Reference attention. q: (B,Sq,Hq,hd); k,v: (B,Sk,Hkv,hd). GQA broadcast.

    ``q_offset`` is the absolute position of q[0] (for decode with a cache).
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    groups = hq // hkv
    qh = q.reshape(b, sq, hkv, groups, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh.astype(jnp.float32), k.astype(jnp.float32))
    logits *= 1.0 / np.sqrt(hd)
    qpos = jnp.arange(sq) + q_offset  # (Sq,)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, hd)


def _attn_bias(sq, block_kv, blk_idx, sk, causal, window, q_offset):
    """Additive (sq, block_kv) fp32 bias: 0 where attendable, NEG_INF where
    masked. Additive form keeps the mask a small 2-D tensor — a boolean
    ``where`` at logits shape gets materialized (and stacked per block) by
    XLA at ~1 GB a pop."""
    kpos = blk_idx * block_kv + jnp.arange(block_kv)
    qpos = jnp.arange(sq) + q_offset
    mask = (kpos[None, :] < sk) & jnp.ones((sq, 1), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)  # (sq, block_kv)


def _mea_forward(q, k, v, sk, causal, window, q_offset, block_kv):
    """Online-softmax forward. Returns (out fp32, lse fp32). Matmuls stay in
    the input dtype with fp32 accumulation (preferred_element_type)."""
    b, sq, hkv, g, hd = q.shape
    nblk = k.shape[1] // block_kv
    kb = k.reshape(b, nblk, block_kv, hkv, hd)
    vb = v.reshape(b, nblk, block_kv, hkv, hd)
    scale = 1.0 / np.sqrt(hd)

    def body(carry, inp):
        acc, m, denom = carry
        kblk, vblk, blk_idx = inp
        logits = jnp.einsum(
            "bqkgd,bskd->bqkgs", q, kblk, preferred_element_type=jnp.float32
        ) * scale
        bias = _attn_bias(sq, block_kv, blk_idx, sk, causal, window, q_offset)
        logits = logits + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        scale_old = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        denom_new = denom * scale_old + jnp.sum(p, axis=-1)
        acc_new = acc * scale_old[..., None] + jnp.einsum(
            "bqkgs,bskd->bqkgd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, denom_new), None

    acc0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    d0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    (acc, m, denom), _ = jax.lax.scan(
        body, (acc0, m0, d0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)),
    )
    denom = jnp.maximum(denom, 1e-30)
    out = acc / denom[..., None]
    lse = m + jnp.log(denom)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _mea(q, k, v, sk, causal, window, q_offset, block_kv):
    out, _ = _mea_forward(q, k, v, sk, causal, window, q_offset, block_kv)
    return out.astype(q.dtype)


def _mea_fwd(q, k, v, sk, causal, window, q_offset, block_kv):
    out, lse = _mea_forward(q, k, v, sk, causal, window, q_offset, block_kv)
    out = out.astype(q.dtype)
    return out, (q, k, v, out, lse)


def _mea_bwd(sk, causal, window, q_offset, block_kv, res, dout):
    """FlashAttention-style backward: recompute p per KV block from saved lse;
    O(Sq * block_kv) live memory, no quadratic residuals."""
    q, k, v, out, lse = res
    b, sq, hkv, g, hd = q.shape
    nblk = k.shape[1] // block_kv
    kb = jnp.moveaxis(k.reshape(b, nblk, block_kv, hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, block_kv, hkv, hd), 1, 0)
    scale = 1.0 / np.sqrt(hd)
    doutf = dout.astype(jnp.float32)
    delta = jnp.sum(doutf * out.astype(jnp.float32), axis=-1)  # (b,sq,hkv,g)

    def body(dq_acc, inp):
        kblk, vblk, blk_idx = inp
        logits = jnp.einsum(
            "bqkgd,bskd->bqkgs", q, kblk, preferred_element_type=jnp.float32
        ) * scale
        bias = _attn_bias(sq, block_kv, blk_idx, sk, causal, window, q_offset)
        logits = logits + bias[None, :, None, None, :]
        p = jnp.exp(logits - lse[..., None])  # (b,sq,hkv,g,s)
        pd = p.astype(dout.dtype)
        dv = jnp.einsum("bqkgs,bqkgd->bskd", pd, dout, preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,bskd->bqkgs", dout, vblk, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dsd = ds.astype(q.dtype)
        dq_blk = jnp.einsum("bqkgs,bskd->bqkgd", dsd, kblk, preferred_element_type=jnp.float32)
        dk = jnp.einsum("bqkgs,bqkgd->bskd", dsd, q, preferred_element_type=jnp.float32)
        return dq_acc + dq_blk, (dk, dv)

    dq, (dks, dvs) = jax.lax.scan(
        body, jnp.zeros(q.shape, jnp.float32),
        (kb, vb, jnp.arange(nblk)),
    )
    dk = jnp.moveaxis(dks, 0, 1).reshape(k.shape).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(v.shape).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


_mea.defvjp(_mea_fwd, _mea_bwd)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    block_kv: int = 1024,
) -> jax.Array:
    """Memory-efficient online-softmax attention (Rabe–Staats / FlashAttention
    algorithm) as pure-jnp ``lax.scan`` over KV blocks with a custom VJP.

    Never materializes the (Sq, Sk) matrix in either pass: the backward
    recomputes per-block probabilities from the saved logsumexp. Residuals are
    O(B·S·H·hd) (q, k, v, out, lse) — this is the compile-anywhere analogue of
    kernels/flash_attention.py and the path used for long-context shapes.
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    groups = hq // hkv
    block_kv = min(block_kv, max(128, sk))
    if sk % block_kv:
        pad = block_kv - sk % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qh = q.reshape(b, sq, hkv, groups, hd)
    out = _mea(qh, k, v, sk, causal, window, q_offset, block_kv)
    return out.reshape(b, sq, hq, hd)


def attention_defs(cfg) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    return {
        "wq": ParamDef((d, nq), (ZERO, TP)),
        "wk": ParamDef((d, nkv), (ZERO, TP)),
        "wv": ParamDef((d, nkv), (ZERO, TP)),
        "wo": ParamDef((nq, d), (TP, ZERO)),
    }


def attention_block(
    params: dict,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array | None = None,
    impl: str = "blockwise",
    block_kv: int = 1024,
) -> jax.Array:
    """Full self-attention over x: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ params["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ params["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(s)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    fn = blockwise_attention if impl == "blockwise" else naive_attention
    kwargs = dict(causal=True, window=cfg.sliding_window)
    if impl == "blockwise":
        kwargs["block_kv"] = min(block_kv, max(s, 128))
    out = fn(q, k, v, **kwargs)
    return out.reshape(b, s, cfg.num_heads * hd) @ params["wo"]


def cross_attention_defs(cfg) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    return {
        "wq": ParamDef((d, nq), (ZERO, TP)),
        "wk": ParamDef((d, nkv), (ZERO, TP)),
        "wv": ParamDef((d, nkv), (ZERO, TP)),
        "wo": ParamDef((nq, d), (TP, ZERO)),
    }


def cross_attention_block(params, x, memory, cfg) -> jax.Array:
    """x: (B,Sq,D) attends over encoder memory (B,Sk,D)."""
    b, sq, _ = x.shape
    sk = memory.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, sq, cfg.num_heads, hd)
    k = (memory @ params["wk"]).reshape(b, sk, cfg.num_kv_heads, hd)
    v = (memory @ params["wv"]).reshape(b, sk, cfg.num_kv_heads, hd)
    out = blockwise_attention(q, k, v, causal=False, block_kv=min(1024, sk))
    return out.reshape(b, sq, cfg.num_heads * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_defs(cfg, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w1": ParamDef((d, ff), (ZERO, TP)),
            "w3": ParamDef((d, ff), (ZERO, TP)),
            "w2": ParamDef((ff, d), (TP, ZERO)),
        }
    return {
        "w1": ParamDef((d, ff), (ZERO, TP)),
        "w2": ParamDef((ff, d), (TP, ZERO)),
    }


def apply_mlp(params: dict, x: jax.Array, kind: str) -> jax.Array:
    h = x @ params["w1"]
    if kind == "swiglu":
        h = jax.nn.silu(h) * (x @ params["w3"])
    elif kind == "geglu":
        h = jax.nn.gelu(h) * (x @ params["w3"])
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    return h @ params["w2"]
