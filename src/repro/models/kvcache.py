"""Decode-time caches and the single-token decode forward.

Cache layout mirrors the superblock structure: per superblock position there
is a stack over repeats — attention positions carry (k, v) of shape
(R, B, S_max, n_kv, hd); mamba positions carry (conv_state, ssm_state). The
decode step scans over repeats, consuming and re-emitting cache slices, so the
HLO stays depth-independent just like training.

Sub-quadratic handling for ``long_500k``: mamba positions are O(1)-state;
attention positions with a sliding window only allocate a window-sized ring
cache (mixtral); full-attention caches are allocated at S_max.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models.model import (
    gather_weights,
    num_repeats,
    shard_act,
    superblock_period,
)


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Per-attention-layer cache length (ring-buffered for SWA)."""
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """ShapeDtypeStruct pytree for the decode cache (no allocation)."""
    p = superblock_period(cfg)
    r = num_repeats(cfg)
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    s_kv = cache_len(cfg, seq_len)
    out: dict[str, Any] = {}
    for j in range(p):
        if cfg.mixer_at(j) == "attention":
            kv = jax.ShapeDtypeStruct((r, batch, s_kv, cfg.num_kv_heads, hd), dt)
            out[f"pos{j}"] = {"k": kv, "v": kv}
        else:
            conv, ssm = M2.mamba2_state_defs(cfg, batch)
            out[f"pos{j}"] = {
                "conv": jax.ShapeDtypeStruct((r,) + conv.shape, conv.dtype),
                "ssm": jax.ShapeDtypeStruct((r,) + ssm.shape, ssm.dtype),
            }
    if cfg.kind == "encdec":
        # precomputed cross-attention K/V over the encoded source
        xkv = jax.ShapeDtypeStruct((r, batch, seq_len, cfg.num_kv_heads, hd), dt)
        for j in range(p):
            out[f"pos{j}"]["xk"] = xkv
            out[f"pos{j}"]["xv"] = xkv
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_specs(cfg, batch, seq_len),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def rope_positions(pos: jax.Array) -> jax.Array:
    """RoPE positions for the decoded token: (1,) for a shared scalar ``pos``,
    (B, 1) for per-slot positions (continuous batching)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((1,), pos, jnp.int32)
    return pos[:, None]


def decode_mask(pos: jax.Array, s_kv: int, sliding: bool) -> jax.Array:
    """Additive attention mask over cache slots at decode position ``pos``.

    Returns (S_kv,) for scalar ``pos`` or (B, S_kv) for per-slot positions.
    Sliding-window caches are ring buffers: every slot is valid once the ring
    has wrapped (pos >= s_kv); before that, validity follows slot order.
    """
    kpos = jnp.arange(s_kv)
    if jnp.ndim(pos):
        kpos = kpos[None, :]
        pos = pos[:, None]
    if sliding:
        valid = (pos >= s_kv) | (kpos <= pos)
    else:
        valid = kpos <= pos
    return jnp.where(valid, 0.0, L.NEG_INF)


def write_slot(buf: jax.Array, val: jax.Array, slot: jax.Array,
               mask: jax.Array | None = None) -> jax.Array:
    """Write one decoded token into a (B, S, ...) cache at ``slot``.

    Scalar ``slot`` keeps the resident fast path (dynamic_update_slice);
    per-slot (B,) writes use a one-hot select over the slot axis — every batch
    row lands at its own position (continuous batching). ``mask`` (B,) bool,
    per-slot only: rows of masked-off slots are left untouched (chunked
    prefill advances a subset of slots while the rest keep their cache).
    """
    val = val.astype(buf.dtype)
    if jnp.ndim(slot) == 0:
        assert mask is None, "write masking requires per-slot positions"
        return jax.lax.dynamic_update_slice_in_dim(buf, val, slot, axis=1)
    rows = jnp.arange(buf.shape[1])[None, :] == slot[:, None]  # (B, S)
    if mask is not None:
        rows = rows & mask[:, None]
    rows = rows.reshape(rows.shape + (1,) * (buf.ndim - 2))
    return jnp.where(rows, val, buf)


class ResidentKV:
    """Default decode cache I/O: the whole (B, S, kv, hd) cache lives in HBM.

    ``update_and_fetch`` is the seam the paged serving subsystem replaces
    (repro.serve.paging.PagedKV): write the decoded token, return the full
    key/value views attention runs over plus the new cache entry — the same
    hook pattern as ``Run.lazy_gather`` for training-weight gathers.
    ``entry_keys`` names the cache leaves the hook consumes per attention
    position (the paged layout splits each of k/v into a hot ring + cold
    pages).
    """

    entry_keys = ("k", "v")

    def update_and_fetch(self, entry: dict, k: jax.Array, v: jax.Array,
                         pos: jax.Array, cfg: ModelConfig,
                         active: jax.Array | None = None):
        s_kv = entry["k"].shape[1]
        slot = pos % s_kv if cfg.sliding_window else pos
        new_k = write_slot(entry["k"], k, slot, mask=active)
        new_v = write_slot(entry["v"], v, slot, mask=active)
        mask = decode_mask(pos, s_kv, bool(cfg.sliding_window))
        return new_k, new_v, mask, {"k": new_k, "v": new_v}


RESIDENT_KV = ResidentKV()


def _decode_attention(ap: dict, h: jax.Array, cache: dict, pos: jax.Array,
                      cfg: ModelConfig, kv_io=None, active=None):
    """h: (B,1,D). Returns (out (B,1,D), new_cache)."""
    b = h.shape[0]
    hd = cfg.resolved_head_dim
    q = (h @ ap["wq"]).reshape(b, 1, cfg.num_heads, hd)
    k = (h @ ap["wk"]).reshape(b, 1, cfg.num_kv_heads, hd)
    v = (h @ ap["wv"]).reshape(b, 1, cfg.num_kv_heads, hd)
    positions = rope_positions(pos)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    kv_io = kv_io or RESIDENT_KV
    attend = getattr(kv_io, "attend", None)
    if attend is not None:
        # fused path: the kv_io owns the whole write+attend (the paged
        # Pallas kernel consumes hot ring + cold pages directly, skipping
        # the gathered full-cache materialization); falls back internally
        # to update_and_fetch + _masked_decode_attn when no kernel applies
        out, new_cache = attend(cache, q, k, v, pos, cfg, active=active)
    else:
        full_k, full_v, logits_mask, new_cache = kv_io.update_and_fetch(
            cache, k, v, pos, cfg, active=active)
        out = _masked_decode_attn(q, full_k, full_v, logits_mask)
    return out.reshape(b, 1, -1) @ ap["wo"], new_cache


def _masked_decode_attn(q, k, v, logits_mask):
    """Single-query attention over the whole cache. q: (B,1,Hq,hd).
    ``logits_mask``: (S_kv,) shared, or (B, S_kv) per-slot (continuous
    batching decodes every batch row at its own position)."""
    b, _, hq, hd = q.shape
    s_kv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qh = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))).reshape(b, hkv, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qh, k.astype(jnp.float32))
    if logits_mask.ndim == 2:
        logits = logits + logits_mask[:, None, None, :]
    else:
        logits = logits + logits_mask[None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def _decode_cross_attention(ap: dict, h: jax.Array, xk: jax.Array, xv: jax.Array, cfg):
    b = h.shape[0]
    hd = cfg.resolved_head_dim
    q = (h @ ap["wq"]).reshape(b, 1, cfg.num_heads, hd)
    out = _masked_decode_attn(q, xk, xv, jnp.zeros((xk.shape[1],), jnp.float32))
    return out.reshape(b, 1, -1) @ ap["wo"]


def decode_position(pparams: dict, x: jax.Array, pcache: dict, pos: jax.Array,
                    cfg: ModelConfig, kv_io=None, active=None):
    """One layer, one token. x: (B,1,D). ``active`` (B,) bool masks cache
    writes for slots not participating in this step (chunked prefill)."""
    h = L.apply_norm(pparams["norm1"], x, cfg.norm)
    new_cache = dict(pcache)
    if "attn" in pparams:
        keys = (kv_io or RESIDENT_KV).entry_keys
        sub = {name: pcache[name] for name in keys}
        mix, upd = _decode_attention(pparams["attn"], h, sub, pos, cfg,
                                     kv_io=kv_io, active=active)
        new_cache.update(upd)
    else:
        state = (pcache["conv"], pcache["ssm"])
        mix, (conv, ssm) = M2.apply_mamba2(pparams["mamba"], h, cfg, state=state, return_state=True)
        if active is not None:
            m = active.reshape((-1,) + (1,) * (conv.ndim - 1))
            conv = jnp.where(m, conv, pcache["conv"])
            m = active.reshape((-1,) + (1,) * (ssm.ndim - 1))
            ssm = jnp.where(m, ssm, pcache["ssm"])
        new_cache.update({"conv": conv, "ssm": ssm})
    x = x + mix
    if "xattn" in pparams:
        hx = L.apply_norm(pparams["norm_x"], x, cfg.norm)
        x = x + _decode_cross_attention(pparams["xattn"], hx, pcache["xk"], pcache["xv"], cfg)
    if "moe" in pparams:
        from repro.models.moe import apply_moe

        h2 = L.apply_norm(pparams["norm2"], x, cfg.norm)
        out, _ = apply_moe(pparams["moe"], h2, cfg)
        x = x + out
    elif "mlp" in pparams:
        h2 = L.apply_norm(pparams["norm2"], x, cfg.norm)
        x = x + L.apply_mlp(pparams["mlp"], h2, cfg.mlp)
    return shard_act(x, "bsd"), new_cache


def decode_step(
    params: dict,
    cache: dict,
    tokens: jax.Array,  # (B, 1) int32 — the token decoded last step
    pos: jax.Array,  # () int32 shared, or (B,) per-slot (continuous batching)
    cfg: ModelConfig,
    *,
    gather_specs=None,
    kv_io=None,
    active=None,  # (B,) bool or None — mask cache writes per slot
) -> tuple[jax.Array, dict]:
    """One decode step across the whole model. Returns (logits (B,V), cache).

    ``kv_io`` swaps the attention-cache storage strategy per position (default
    ``RESIDENT_KV``); the paged serving path passes ``serve.paging.PagedKV``,
    whose cold pages live in host memory and are fetched page-wise inside this
    same repeat scan — mirroring how ``Run.lazy_gather`` threads per-chunk
    weight gathers through the training scan.
    """
    from repro.models.model import embed_tokens, lm_head

    x = embed_tokens(params, tokens, cfg)
    p = superblock_period(cfg)

    def body(x, slices):
        new_slices = {}
        for j in range(p):
            specs = None if gather_specs is None else gather_specs[f"pos{j}"]
            pp = gather_weights(slices[f"pos{j}"]["params"], specs)
            x, nc = decode_position(pp, x, slices[f"pos{j}"]["cache"], pos, cfg,
                                    kv_io=kv_io, active=active)
            new_slices[f"pos{j}"] = nc
        return x, new_slices

    xs = {
        f"pos{j}": {"params": params["blocks"][f"pos{j}"], "cache": cache[f"pos{j}"]}
        for j in range(p)
    }
    x, new_cache = jax.lax.scan(body, x, xs)
    logits = lm_head(params, x, cfg)
    return logits[:, 0], new_cache
