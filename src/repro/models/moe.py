"""Mixture-of-Experts layer: top-k token-choice routing with capacity-based
einsum dispatch (Shazeer-style), expert-parallel friendly.

The dispatch/combine formulation keeps everything as dense einsums so XLA SPMD
can shard the expert dimension over the ``model`` mesh axis (expert
parallelism) and the token dimension over ``data`` — the all-to-all shows up
naturally in the lowered HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import EXP, NONE, TP, ZERO, ParamDef, apply_mlp


def moe_defs(cfg) -> dict:
    mc = cfg.moe
    d = cfg.d_model
    de = mc.d_expert or cfg.d_ff
    gated = cfg.mlp in ("swiglu", "geglu")
    defs = {
        "router": ParamDef((d, mc.num_experts), (ZERO, NONE), scale=0.02, dtype="float32"),
        "w1": ParamDef((mc.num_experts, d, de), (EXP, ZERO, NONE)),
        "w2": ParamDef((mc.num_experts, de, d), (EXP, NONE, ZERO)),
    }
    if gated:
        defs["w3"] = ParamDef((mc.num_experts, d, de), (EXP, ZERO, NONE))
    if mc.num_shared_experts:
        ds = de * mc.num_shared_experts
        defs["shared_w1"] = ParamDef((d, ds), (ZERO, TP))
        defs["shared_w2"] = ParamDef((ds, d), (TP, ZERO))
        if gated:
            defs["shared_w3"] = ParamDef((d, ds), (ZERO, TP))
    return defs


def _top_k_gating(logits: jax.Array, top_k: int):
    """logits: (T, E) -> (weights (T,k), indices (T,k), aux_loss)."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    num_experts = logits.shape[-1]
    one_hot = jax.nn.one_hot(indices, num_experts, dtype=jnp.float32)  # (T,k,E)
    tokens_per_expert = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)  # fraction (E,)
    mean_probs = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(tokens_per_expert * mean_probs)
    return weights, indices, one_hot, aux


def apply_moe(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar).

    Capacity-based dispatch: each expert processes at most
    C = ceil(top_k * T / E * capacity_factor) tokens; overflow is dropped
    (contributes the residual stream only), matching standard TPU MoE practice.
    """
    import math

    mc = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32)) @ params["router"]
    weights, indices, one_hot, aux = _top_k_gating(logits, mc.top_k)

    capacity = max(math.ceil(mc.top_k * t * mc.capacity_factor / mc.num_experts), 1)
    # position of each (token, k) slot within its expert's buffer
    flat_choice = one_hot  # (T,k,E)
    # cumulative count over (token-major, k) order
    cum = jnp.cumsum(flat_choice.reshape(t * mc.top_k, mc.num_experts), axis=0)
    pos_in_expert = (cum - 1).reshape(t, mc.top_k, mc.num_experts)
    within_cap = (pos_in_expert < capacity) & (flat_choice > 0)
    pos_clipped = jnp.clip(pos_in_expert, 0, capacity - 1).astype(jnp.int32)
    cap_one_hot = jax.nn.one_hot(pos_clipped, capacity, dtype=jnp.float32)
    # dispatch: (T, E, C)
    dispatch = jnp.einsum("tke,tkec->tec", jnp.where(within_cap, 1.0, 0.0), cap_one_hot)
    combine = jnp.einsum(
        "tke,tkec->tec",
        jnp.where(within_cap, weights[..., None].astype(jnp.float32), 0.0),
        cap_one_hot,
    )
    expert_in = jnp.einsum("tec,td->ecd", dispatch, xt.astype(jnp.float32)).astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", expert_in, params["w1"])
    if "w3" in params:
        gate = jnp.einsum("ecd,edf->ecf", expert_in, params["w3"])
        h = jax.nn.silu(h) * gate if cfg.mlp == "swiglu" else jax.nn.gelu(h) * gate
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(h))
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w2"])
    out = jnp.einsum("tec,ecd->td", combine, expert_out.astype(jnp.float32)).astype(x.dtype)

    if mc.num_shared_experts:
        shared = {k[len("shared_") :]: v for k, v in params.items() if k.startswith("shared_")}
        out = out + apply_mlp(shared, xt, cfg.mlp if "shared_w3" in params else "gelu")
    return out.reshape(b, s, d), aux * mc.aux_loss_weight
