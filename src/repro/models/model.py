"""Model assembly: superblocks, decoder / encoder-decoder forward, decode.

A *superblock* is the smallest repeating unit of layers — ``lcm(len(mixer
pattern), moe.every)`` layers (1 for uniform archs, 8 for Jamba). Parameters
are stacked over superblock repeats so the layer stack lowers to a single
``lax.scan`` regardless of depth; this is also the chunk granularity used by
ProTrain's planner (paper §B.1 groups one transformer block per chunk).

The layer stack is executed as a list of *runs* — contiguous repeat ranges
sharing one (weights-buffered?, activation-policy) pair — which is how the
planner's {n_persist, n_buffer, n_swap, n_checkpoint} choice is realized (see
train/step_builder.py).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models.layers import NONE, TP, ZERO, LAYER, ParamDef

ACT = "act"  # checkpoint_name for offloadable activations
ACT_CMP = "act_cmp"  # checkpoint_name for compressed (quantized) activations
GATHERED_W = "gathered_w"  # checkpoint_name for gathered (unsharded) weights


def superblock_period(cfg: ModelConfig) -> int:
    p = len(cfg.mixer_pattern)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    return p


def num_repeats(cfg: ModelConfig) -> int:
    p = superblock_period(cfg)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return cfg.num_layers // p


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------
def _position_defs(cfg: ModelConfig, pos: int, cross_attention: bool = False) -> dict:
    """ParamDefs for one layer position within the superblock."""
    defs: dict[str, Any] = {"norm1": L.norm_defs(cfg.d_model, cfg.norm)}
    if cfg.mixer_at(pos) == "attention":
        defs["attn"] = L.attention_defs(cfg)
    else:
        defs["mamba"] = M2.mamba2_defs(cfg)
    if cross_attention:
        defs["norm_x"] = L.norm_defs(cfg.d_model, cfg.norm)
        defs["xattn"] = L.cross_attention_defs(cfg)
    if cfg.moe_at(pos):
        defs["norm2"] = L.norm_defs(cfg.d_model, cfg.norm)
        defs["moe"] = MOE.moe_defs(cfg)
    elif cfg.d_ff:
        defs["norm2"] = L.norm_defs(cfg.d_model, cfg.norm)
        defs["mlp"] = L.mlp_defs(cfg)
    return defs


def _stack_defs(defs, n: int):
    """Prepend a stacked LAYER axis of size n to every ParamDef."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (LAYER,) + d.axes, init=d.init, scale=d.scale, dtype=d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def param_defs(cfg: ModelConfig) -> dict:
    """Full parameter ParamDef pytree for the model."""
    p = superblock_period(cfg)
    r = num_repeats(cfg)
    defs: dict[str, Any] = {
        "embed": {"tok": ParamDef((cfg.vocab_size, cfg.d_model), (TP, ZERO), scale=0.02)},
        "blocks": {
            f"pos{j}": _stack_defs(_position_defs(cfg, j, cross_attention=cfg.kind == "encdec"), r)
            for j in range(p)
        },
        "final_norm": L.norm_defs(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        defs["head"] = {"w": ParamDef((cfg.d_model, cfg.vocab_size), (ZERO, TP), scale=0.02)}
    if cfg.kind == "encdec":
        defs["encoder"] = {
            "blocks": _stack_defs(_position_defs(cfg, 0), cfg.encoder_layers),
            "final_norm": L.norm_defs(cfg.d_model, cfg.norm),
        }
    if cfg.dtype != "bfloat16":
        # ParamDefs default to bf16 compute dtype; explicit fp32 defs
        # (A_log, router, ...) keep theirs.
        defs = jax.tree.map(
            lambda d: dataclasses.replace(d, dtype=cfg.dtype) if d.dtype == "bfloat16" else d,
            defs,
            is_leaf=lambda x: isinstance(x, ParamDef),
        )
    return defs


def init_params(cfg: ModelConfig, key: jax.Array):
    return L.init_tree(param_defs(cfg), key)


# ---------------------------------------------------------------------------
# Activation sharding hook (set by the step builder; no-op by default)
# ---------------------------------------------------------------------------
_ACT_SHARDER: Callable[[jax.Array, str], jax.Array] = lambda x, kind: x


def set_activation_sharder(fn) -> None:
    global _ACT_SHARDER
    _ACT_SHARDER = fn


def _pin_cotangent_dtype(x: jax.Array) -> jax.Array:
    """Identity whose VJP casts the incoming cotangent back to x.dtype.

    Mixed-precision transposes (fp32-accumulating einsums, fp32 loss heads)
    otherwise promote dL/dx to fp32 at every block boundary — doubling the
    backward activation traffic and the saved-residual stacks.
    """

    @jax.custom_vjp
    def pin(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, ct):
        return (ct.astype(x.dtype),)

    pin.defvjp(fwd, bwd)
    return pin(x)


def shard_act(x: jax.Array, kind: str = "bsd") -> jax.Array:
    if kind == "bsd":
        x = _pin_cotangent_dtype(x)
    return _ACT_SHARDER(x, kind)


# ---------------------------------------------------------------------------
# Compressed activation saves (quantize-on-save / dequantize-on-use)
# ---------------------------------------------------------------------------
# Tri-state dispatch for the int8 activation quantizer, mirroring
# dist.collectives.set_fused_quant: None = auto (the PR-8 fused Pallas
# quantize kernel when it can run *compiled* — interpret mode unrolls the
# (rows,) grid and is unusable at activation sizes), True/False = forced.
_ACT_QUANT_KERNEL: bool | None = None


def set_act_quant_kernel(enabled: bool | None) -> None:
    global _ACT_QUANT_KERNEL
    _ACT_QUANT_KERNEL = enabled


def act_quant_kernel_active() -> bool:
    if _ACT_QUANT_KERNEL is not None:
        return _ACT_QUANT_KERNEL
    from repro.compat import pallas_interpret_required, pallas_supported

    return pallas_supported() and not pallas_interpret_required()


def _quantize_rows(x2d: jax.Array):
    """Per-row absmax int8 quantize of a (rows, d) fp32 array -> (q, scale).

    Dispatches to the fused Pallas quantize/pack kernel (kernels package)
    when it runs compiled, else the vectorized ref oracle — the two are
    bitwise-identical (tests/test_paged_attention_kernel.py), so the seam
    never changes numerics, only where the bytes are produced."""
    me = jnp.int32(0)  # EF slot unused for activations: the error is discarded
    if act_quant_kernel_active():
        from repro.kernels import fused_quantize_ef

        q, s, _ = fused_quantize_ef(x2d, me)
    else:
        from repro.kernels.ref import fused_quantize_ef_ref

        q, s, _ = fused_quantize_ef_ref(x2d, me)
    return q, s


def compress_act(x: jax.Array, mode: str = "compress8") -> jax.Array:
    """Save-compressed seam: the activation twin of ``Run.lazy_gather``.

    Under a ``save_only_these_names(ACT_CMP, ...)`` remat policy the block
    holds only the quantized payload FWD->BWD and dequantizes at point of
    use in the backward replay; everything between compressed sites is
    rematerialized. Two parts make that true:

      * the quantized payload (q, scale) is produced by *named plain eqn
        outputs* (``checkpoint_name(·, ACT_CMP)``) — custom_vjp residuals do
        not persist under jax.checkpoint, named saveables do. The quantizer
        itself is wrapped in a custom_vjp so AD never traces the Pallas call
        (its cotangent to x is zero — the gradient does not flow through the
        rounding);
      * a dequantize-on-use custom_vjp ``use(q, s, x)`` whose primal reads
        ONLY (q, s) — so the replay reconstructs the activation from the
        saved payload, not from x — and whose VJP routes the cotangent
        straight through to x (the straight-through estimator; absmax
        clipping makes the identity exact up to rounding).

    ``compress16`` is the degenerate lattice point: a named bf16 downcast
    (linear, differentiable — no custom_vjp needed).
    """
    if mode == "compress16":
        return checkpoint_name(x.astype(jnp.bfloat16), ACT_CMP).astype(x.dtype)
    assert mode == "compress8", mode
    dtype = x.dtype
    shape = x.shape
    rows = math.prod(shape[:-1])
    x2d = x.astype(jnp.float32).reshape(rows, shape[-1])

    @jax.custom_vjp
    def quantize(x2d):
        return _quantize_rows(x2d)

    def q_fwd(x2d):
        return quantize(x2d), None

    def q_bwd(_, ct):
        return (jnp.zeros((rows, shape[-1]), jnp.float32),)

    quantize.defvjp(q_fwd, q_bwd)
    q, s = quantize(x2d)
    q = checkpoint_name(q, ACT_CMP)
    s = checkpoint_name(s, ACT_CMP)

    def _deq(q, s):
        return (q.astype(jnp.float32) * s[:, None]).reshape(shape).astype(dtype)

    @jax.custom_vjp
    def use(q, s, x):
        return _deq(q, s)

    def u_fwd(q, s, x):
        return _deq(q, s), None

    def u_bwd(_, ct):
        return (np.zeros((rows, shape[-1]), jax.dtypes.float0),
                jnp.zeros((rows,), jnp.float32), ct.astype(dtype))

    use.defvjp(u_fwd, u_bwd)
    return use(q, s, x)


def save_act(x: jax.Array, mode: str = "none") -> jax.Array:
    """Tag an activation save site: compressed for the compress policies,
    the plain offloadable ACT name otherwise."""
    if mode in ("compress8", "compress16"):
        return compress_act(x, mode)
    return checkpoint_name(x, ACT)


def gather_weights(params, specs=None):
    """Mark weights as gathered at point-of-use (named for remat policies).

    ``specs`` is an optional matching pytree of ``NamedSharding`` whose ZeRO
    axes have been dropped (replicated): the ``with_sharding_constraint``
    forces the all-gather here — per scanned superblock, i.e. chunk-wise, the
    paper's gather granularity. For persistent runs specs is None (weights are
    already replicated; the name alone is harmless).
    """
    if specs is None:
        return jax.tree.map(lambda w: checkpoint_name(w, GATHERED_W), params)
    # device_put (not with_sharding_constraint): it both forces the all-gather
    # over the dropped ZeRO axes *and* moves host-resident chunks into HBM.
    # The optimization barrier pins the gather *inside* the layer scan: without
    # it XLA commutes slice-of-stack with all-gather and hoists the gather of
    # the whole stacked run out of the loop — materializing every layer's
    # weights at once (the exact pattern chunk-wise gathering must avoid).
    params = optimization_barrier(params)
    return jax.tree.map(
        lambda w, s: checkpoint_name(w if s is None else jax.device_put(w, s), GATHERED_W),
        params,
        specs,
    )


# ---------------------------------------------------------------------------
# Superblock forward
# ---------------------------------------------------------------------------
def apply_position(
    pparams: dict,
    x: jax.Array,
    cfg: ModelConfig,
    pos_j: int,
    *,
    positions: jax.Array | None = None,
    memory: jax.Array | None = None,
    attn_impl: str = "blockwise",
    act_mode: str = "none",
) -> tuple[jax.Array, jax.Array]:
    """One layer (superblock position). Returns (x, aux_loss).

    ``act_mode``: how this layer's save sites are tagged — "none" names them
    ACT (keep/offload/remat decided by the surrounding policy), the compress
    modes route them through the quantize-on-save seam (``save_act``)."""
    aux = jnp.zeros((), jnp.float32)
    x = shard_act(x, "enter")  # SP: gather seq-sharded boundary for compute
    h = L.apply_norm(pparams["norm1"], x, cfg.norm)
    h = save_act(h, act_mode)
    if "attn" in pparams:
        mix = L.attention_block(pparams["attn"], h, cfg, positions=positions, impl=attn_impl)
    else:
        mix = M2.apply_mamba2(pparams["mamba"], h, cfg)
    x = x + save_act(mix, act_mode)
    if memory is not None and "xattn" in pparams:
        hx = L.apply_norm(pparams["norm_x"], x, cfg.norm)
        x = x + save_act(L.cross_attention_block(pparams["xattn"], hx, memory, cfg), act_mode)
    if "moe" in pparams:
        h2 = L.apply_norm(pparams["norm2"], x, cfg.norm)
        out, moe_aux = MOE.apply_moe(pparams["moe"], h2, cfg)
        x = x + save_act(out, act_mode)
        aux = aux + moe_aux
    elif "mlp" in pparams:
        h2 = L.apply_norm(pparams["norm2"], x, cfg.norm)
        x = x + save_act(L.apply_mlp(pparams["mlp"], h2, cfg.mlp), act_mode)
    return shard_act(x), aux


def apply_superblock(
    block_params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    gather_specs=None,
    remat_policy=None,
    lazy_gather=None,
    ef=None,
    **kw,
):
    """block_params: {posJ: params-for-one-repeat}. Returns (x, aux).

    ``remat_policy``: optional jax.checkpoint policy applied *per position*
    (per transformer layer) — the paper's per-block activation management
    granularity. The gather is inside the rematted region, so gathered-weight
    save/offload follows the same policy (n_buffer semantics).

    ``lazy_gather``: manual-sync (shard_map) replacement for the
    device_put-based ``gather_weights``: a hook ``(per-position params,
    per-position EF subtree, position j) -> gathered params`` built on
    ``dist.collectives.gather_param_lazy``, whose VJP reduce-scatters the
    gradient to shard owners. ``ef`` is the error-feedback residual subtree
    threaded to the hook (sliced alongside the params by the run scan).
    """
    aux = jnp.zeros((), jnp.float32)

    def one(j, x):
        if lazy_gather is not None:
            pp = lazy_gather(block_params[f"pos{j}"],
                             None if ef is None else ef[f"pos{j}"], j)
        else:
            specs = None if gather_specs is None else gather_specs[f"pos{j}"]
            pp = gather_weights(block_params[f"pos{j}"], specs)
        return apply_position(pp, x, cfg, j, **kw)

    for j in range(superblock_period(cfg)):
        fn = one if remat_policy is None else jax.checkpoint(one, policy=remat_policy, static_argnums=(0,))
        x, a = fn(j, x)
        aux = aux + a
    return x, aux


REMAT_POLICIES: dict[tuple[str, bool, bool], Any] = {}


def _is_lazy_gather_eqn(prim, params) -> bool:
    """Recognize the ``dist.collectives.gather_param_lazy`` custom_vjp call:
    a custom-vjp whose forward jaxpr is (only) a tiled all-gather."""
    if prim.name not in ("custom_vjp_call_jaxpr", "custom_vjp_call"):
        return False
    fj = params.get("fun_jaxpr") or params.get("call_jaxpr")
    eqns = getattr(getattr(fj, "jaxpr", fj), "eqns", [])
    return 0 < len(eqns) <= 2 and any(
        e.primitive.name == "all_gather" for e in eqns)


def _save_acts_not_lazy_gathers():
    """save_anything_except_these_names(GATHERED_W), plus: never save the
    *raw* all-gather output feeding the name. Without the second clause the
    name exclusion is defeated — the named value is an identity of the
    unnamed gather output, so partial-eval happily saves the unnamed ancestor
    and the "re-gather in BWD" semantics silently becomes "buffered". By the
    time the policy runs the gather custom_vjp has been inlined, so the
    exclusion matches the ``all_gather`` primitive itself (the only
    all-gathers inside a lazy run's remat region are the lazy weight
    gathers; activation sharding is identity under manual sync) — with the
    custom_vjp-eqn matcher kept for jax versions that keep the call
    un-inlined."""
    base = jax.checkpoint_policies.save_anything_except_these_names(GATHERED_W)

    def policy(prim, *avals, **params):
        if prim.name == "all_gather" or _is_lazy_gather_eqn(prim, params):
            return False
        return base(prim, *avals, **params)

    return policy


def _remat_policy(act_policy: str, buffered: bool, lazy: bool = False):
    """Map (activation policy, weights-buffered?) to a jax.checkpoint policy.

    ``lazy``: the run gathers weights through ``gather_param_lazy`` (manual
    ZeRO-3) — the unbuffered keep-activations policy must then also exclude
    the gather custom_vjp's raw output from saving (see
    ``_save_acts_not_lazy_gathers``)."""
    key = (act_policy, buffered, lazy)
    if key in REMAT_POLICIES:
        return REMAT_POLICIES[key]
    cp = jax.checkpoint_policies
    if act_policy == "none":
        if buffered:
            pol = cp.everything_saveable
        elif lazy:
            pol = _save_acts_not_lazy_gathers()
        else:
            pol = cp.save_anything_except_these_names(GATHERED_W)
    elif act_policy == "checkpoint":
        pol = cp.save_only_these_names(GATHERED_W) if buffered else cp.nothing_saveable
    elif act_policy in ("compress8", "compress16"):
        # save the quantized payload (and the gathered weights when the run
        # buffers them); save_only_* default-excludes everything else, so the
        # ZeRO-3 lazy gathers are never saved — let alone quantized — and the
        # interiors between compressed sites rematerialize from the payload
        pol = (cp.save_only_these_names(ACT_CMP, GATHERED_W) if buffered
               else cp.save_only_these_names(ACT_CMP))
    elif act_policy == "swap":
        pol = cp.save_and_offload_only_these_names(
            names_which_can_be_saved=[GATHERED_W] if buffered else [],
            names_which_can_be_offloaded=[ACT],
            offload_src="device",
            offload_dst="pinned_host",
        )
    else:
        raise ValueError(act_policy)
    REMAT_POLICIES[key] = pol
    return pol


@dataclasses.dataclass
class Run:
    """A contiguous range of superblock repeats sharing one policy."""

    params: dict  # stacked over this run's repeats
    n_repeats: int
    act_policy: str = "none"  # none | checkpoint | swap | compress8 | compress16
    buffered: bool = True  # gathered weights saved fwd->bwd?
    persistent: bool = False  # params replicated over zero axes (no gather)
    gather_specs: Any = None  # per-repeat pytree of NamedSharding (ZeRO dropped)
    ckpt_group: int = 1  # remat region size in superblock repeats (sqrt(n) trade)
    # manual ZeRO-3 lazy gather: hook (per-repeat params, per-repeat ef) ->
    # gathered params, plus the stacked EF residual tree scanned alongside the
    # params so the gather VJP's new residuals come out stacked per repeat
    lazy_gather: Any = None
    ef: Any = None
    # double-buffered gather prefetch (plan.gather_prefetch_depth >= 2):
    # inside the run scan, repeat k+1's all-gathers are issued during repeat
    # k's matmuls, barrier-ordered after repeat k-1's output — the training
    # twin of serve/paging's cold-page prefetch. Only meaningful for
    # buffered lazy-gather runs (the carried gathered weights are saved
    # FWD->BWD anyway); everything else falls back to the serial inline
    # gather automatically.
    prefetch: bool = False


def apply_runs(
    runs: list[Run],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    memory: jax.Array | None = None,
    attn_impl: str = "blockwise",
) -> tuple[jax.Array, jax.Array]:
    """Execute the layer stack as policy runs of scanned superblocks."""
    aux_total = jnp.zeros((), jnp.float32)

    for run in runs:
        # per-position (per-layer) remat policy; None = save everything
        lazy = run.lazy_gather is not None
        pol = (
            None
            if run.act_policy == "none" and run.buffered
            else _remat_policy(run.act_policy, run.buffered, lazy)
        )
        g = run.ckpt_group if run.act_policy == "checkpoint" else 1
        g = max(1, min(g, run.n_repeats))
        while run.n_repeats % g:
            g -= 1  # group must tile the run
        act_mode = (run.act_policy
                    if run.act_policy in ("compress8", "compress16") else "none")

        if (run.prefetch and lazy and run.buffered and run.act_policy == "none"
                and g == 1 and run.n_repeats >= 2):
            x, aux_total = _apply_run_prefetched(
                run, x, aux_total, cfg, memory=memory, attn_impl=attn_impl)
            continue

        if g == 1:
            def body(carry, sl, _run=run, _pol=pol, _mode=act_mode):
                x, aux = carry
                bp, ef = sl
                x, a = apply_superblock(
                    bp, x, cfg, gather_specs=_run.gather_specs, remat_policy=_pol,
                    lazy_gather=_run.lazy_gather, ef=ef,
                    memory=memory, attn_impl=attn_impl, act_mode=_mode,
                )
                return (x, aux + a), None

            scan_xs = (run.params, run.ef)
        else:
            # grouped remat: one checkpoint region spans g superblocks, so the
            # scan saves one boundary per g layers (recompute working set: g)
            def region(carry, gsl, _run=run, _g=g):
                x, aux = carry
                gp, gef = gsl
                for i in range(_g):
                    bp = jax.tree.map(lambda a, _i=i: a[_i], gp)
                    ef_i = (None if gef is None
                            else jax.tree.map(lambda a, _i=i: a[_i], gef))
                    x, a = apply_superblock(
                        bp, x, cfg, gather_specs=_run.gather_specs,
                        remat_policy=None, lazy_gather=_run.lazy_gather,
                        ef=ef_i, memory=memory, attn_impl=attn_impl,
                    )
                    aux = aux + a
                return (x, aux)

            region_ck = jax.checkpoint(
                region, policy=_remat_policy(run.act_policy, run.buffered, lazy))

            def body(carry, gsl, _f=region_ck):
                return _f(carry, gsl), None

            scan_xs = jax.tree.map(
                lambda a, _g=g: a.reshape(a.shape[0] // _g, _g, *a.shape[1:]),
                (run.params, run.ef),
            )

        n_iters = run.n_repeats // g
        if n_iters == 1:
            (x, aux_total), _ = body(
                (x, aux_total), jax.tree.map(lambda a: a[0], scan_xs)
            )
        else:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), scan_xs)
    return x, aux_total


def _apply_run_prefetched(run: Run, x, aux_total, cfg, *, memory, attn_impl):
    """Double-buffered lazy-gather pipeline over one buffered run.

    Serial inline gathering (the non-prefetch path) only lets repeat k's
    all-gather start once repeat k-1's output exists — gather and matmuls
    alternate. Here repeat 0's weights are gathered before the scan and the
    scan body, at repeat k, (a) issues repeat k+1's gathers *anchored on the
    incoming activation* (repeat k-1's output — the earliest point the
    pipeline may start them, and nothing orders them after repeat k's
    compute) and (b) applies repeat k with the weights carried from the
    previous iteration. Exactly two repeats' gathered weights are ever in
    flight (``plan.gather_prefetch_depth == 2``), mirroring serve/paging's
    ``optimization_barrier`` cold-page double buffer.

    The scan runs ``n_repeats - 1`` iterations over the ``[1:]`` param/EF
    slices, with a trailing un-scanned apply for the last repeat — NOT a
    wrap-around gather of repeat 0, which would consume repeat 0's EF
    residual twice and corrupt the error-feedback semantics (the residual's
    cotangents from two gathers would add).

    Restricted to buffered ``act_policy="none"`` runs: the carried gathered
    weights become per-iteration scan AD residuals, which is free exactly
    when the run saves them FWD->BWD anyway. Unbuffered/checkpointed runs
    keep the serial inline gather (the documented fallback).
    """

    def gather_repeat(bp, efr, anchor=None, _run=run):
        return {
            k: _run.lazy_gather(bp[k], None if efr is None else efr[k],
                                int(k[3:]), anchor=anchor)
            for k in bp
        }

    first = jax.tree.map(lambda a: a[0], (run.params, run.ef))
    w0 = gather_repeat(*first)
    rest_xs = jax.tree.map(lambda a: a[1:], (run.params, run.ef))

    def body(carry, sl):
        x, aux, w_cur = carry
        bp, ef = sl
        w_next = gather_repeat(bp, ef, anchor=x)
        x, a = apply_superblock(
            w_cur, x, cfg, gather_specs=None, remat_policy=None,
            lazy_gather=None, ef=None, memory=memory, attn_impl=attn_impl,
        )
        return (x, aux + a, w_next), None

    (x, aux_total, w_last), _ = jax.lax.scan(body, (x, aux_total, w0), rest_xs)
    x, a = apply_superblock(
        w_last, x, cfg, gather_specs=None, remat_policy=None,
        lazy_gather=None, ef=None, memory=memory, attn_impl=attn_impl,
    )
    return x, aux_total + a


def default_runs(cfg: ModelConfig, params: dict) -> list[Run]:
    """Single fully-resident run (no ZeRO, no remat) — small-model default."""
    return [Run(params=params["blocks"], n_repeats=num_repeats(cfg), persistent=True)]


# ---------------------------------------------------------------------------
# Full-model forward
# ---------------------------------------------------------------------------
def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    emb = params["embed"]["tok"]
    return shard_act(jnp.take(emb, tokens, axis=0), "bsd")


def lm_head(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    return shard_act(x @ w, "logits")


def encode(params: dict, frames: jax.Array, cfg: ModelConfig, gather_specs=None) -> jax.Array:
    """Encoder stack over precomputed frontend embeddings (B, S_src, D)."""
    enc = params["encoder"]
    x = shard_act(frames, "bsd")

    def body(carry, bp):
        x = carry
        pp = gather_weights(bp, gather_specs)
        h = L.apply_norm(pp["norm1"], x, cfg.norm)
        b, s, d = h.shape
        hd = cfg.resolved_head_dim
        q = (h @ pp["attn"]["wq"]).reshape(b, s, cfg.num_heads, hd)
        k = (h @ pp["attn"]["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
        v = (h @ pp["attn"]["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
        pos = jnp.arange(s)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        o = L.blockwise_attention(q, k, v, causal=False, block_kv=min(1024, s))
        x = x + checkpoint_name(o.reshape(b, s, -1) @ pp["attn"]["wo"], ACT)
        h2 = L.apply_norm(pp["norm2"], x, cfg.norm)
        x = x + checkpoint_name(L.apply_mlp(pp["mlp"], h2, cfg.mlp), ACT)
        return shard_act(x), None

    body_ck = jax.checkpoint(body, policy=_remat_policy("checkpoint", True))
    x, _ = jax.lax.scan(body_ck, x, enc["blocks"])
    return L.apply_norm(enc["final_norm"], x, cfg.norm)


def forward(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    *,
    runs: list[Run] | None = None,
    attn_impl: str = "blockwise",
    encoder_gather_specs=None,
) -> tuple[jax.Array, jax.Array]:
    """Training/prefill forward. ``batch`` keys: tokens (B,S) and optionally
    frames (B,S_src,D) [encdec] or patches (B,S_img,D) [vlm].
    Returns (hidden (B,S,D), aux_loss)."""
    x = embed_tokens(params, batch["tokens"], cfg)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    memory = None
    if cfg.kind == "encdec":
        memory = encode(params, batch["frames"], cfg, gather_specs=encoder_gather_specs)
    if runs is None:
        runs = default_runs(cfg, params)
    x, aux = apply_runs(runs, x, cfg, memory=memory, attn_impl=attn_impl)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        x = x[:, batch["patches"].shape[1] :]
    return x, aux
