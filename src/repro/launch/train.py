"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

Selects the architecture, runs the ProTrain automatic memory-management
search for the *local* hardware (CPU devices here; TPU v5e constants when
--target-hw tpu-v5e is passed for plan inspection), builds the plan-realized
train step, and runs the fault-tolerant loop with checkpointing + auto-resume.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import TPU_V5E, build_workload, search
from repro.core.hardware import HARDWARE, MeshSpec
from repro.core.plan import MemoryPlan, fully_resident_plan
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.optim.adam import AdamConfig, cosine_schedule
from repro.train.loop import LoopConfig, train_loop
from repro.train.step_builder import build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke-scale) variant of the arch")
    ap.add_argument("--target-hw", default=None, choices=[None, *HARDWARE],
                    help="plan against this hardware spec instead of local")
    ap.add_argument("--plan", default="auto", choices=["auto", "resident", "fsdp"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_local_mesh()
    n_dev = len(jax.devices())
    mspec = MeshSpec(tuple(mesh.devices.shape), tuple(mesh.axis_names))

    from repro.core.chunks import chunk_inventory
    from repro.models.model import num_repeats

    nc = len(chunk_inventory(cfg))
    nb = num_repeats(cfg)
    if args.plan == "auto":
        hw = HARDWARE[args.target_hw] if args.target_hw else TPU_V5E
        w = build_workload(cfg, shape, mspec, hw)
        res = search(w, sp="auto")
        plan = res.plan
        print(f"[train] searched plan: {plan.describe()} "
              f"(modeled t_iter={res.runtime.t_iteration:.3f}s on {hw.name})")
        if args.target_hw is None:
            # local CPU run: memory-kind offload is pointless; keep the block
            # policies but park chunks on device
            plan = dataclasses.replace(plan, n_host=0, n_persist=plan.n_chunks
                                       - 0, n_buffer=0)
    elif args.plan == "fsdp":
        plan = MemoryPlan(n_chunks=nc, n_blocks=nb, n_checkpoint=nb)
    else:
        plan = fully_resident_plan(nc, nb)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={n_dev} plan={plan.describe()}")

    art = build_train_step(
        cfg, plan, mesh, shape,
        adam=AdamConfig(lr=args.lr),
        lr_schedule=cosine_schedule(args.lr, warmup=min(20, args.steps // 10 + 1),
                                    total=args.steps),
    )
    pipe = SyntheticTokenPipeline(cfg, shape, seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    res = train_loop(
        art, pipe, mgr,
        LoopConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                   log_every=max(1, args.steps // 20)),
        init_key=jax.random.PRNGKey(args.seed),
    )
    print(json.dumps({
        "arch": cfg.name,
        "steps": res.steps_run,
        "first_loss": res.losses[0] if res.losses else None,
        "final_loss": res.losses[-1] if res.losses else None,
        "resumed_from": res.resumed_from,
        "straggler_events": res.straggler_events,
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
