"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so that
importing this module does not touch jax device state — the dry-run must set
XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

import jax

from repro.compat import ensure_jax_compat
from repro.core.hardware import MULTI_POD, SINGLE_POD, MeshSpec

ensure_jax_compat()  # API shims only — no backend/device initialization


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_spec(*, multi_pod: bool = False) -> MeshSpec:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_local_mesh(devices=None):
    """Single-process mesh over whatever devices exist (tests/examples)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and n >= cand:
            model = cand
            break
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
