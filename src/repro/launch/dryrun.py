import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k

Writes one JSON line per cell to reports/dryrun_cells.jsonl (append; completed
cells are skipped on re-run, so a crashed sweep resumes).
"""
import argparse
import json
import traceback

import jax

from repro.configs import ARCHS, get_config, shapes_for, get_shape
from repro.core import TPU_V5E, build_workload, search
from repro.core.cost_model import serve_totals, step_totals
from repro.core.plan import MemoryPlan
from repro.core.serve_plan import serve_memory_estimate, serve_plan
from repro import obs
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh, mesh_spec
from repro.train.step_builder import build_decode_step, build_prefill_step, build_train_step

REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports")


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, sp: str = "off",
             plan_override: MemoryPlan | None = None, hlo_out: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mspec = mesh_spec(multi_pod=multi_pod)
    hw = TPU_V5E
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "mode": shape.mode, "sp": sp,
    }
    # one clock: the lower/compile timings come from obs spans (a disabled
    # tracer still measures dur_s), so an installed telemetry handle sees
    # the same regions the report records. The lower span brackets the
    # whole mode-specific build+lower branch, so it is entered manually.
    tracer = obs.current_telemetry().tracer
    lower_span = tracer.span("dryrun.lower", arch=arch, shape=shape_name)
    lower_span.__enter__()

    if shape.is_training:
        from repro.core import estimate_memory, estimate_runtime

        w = build_workload(cfg, shape, mspec, hw)
        if plan_override is not None:
            plan = plan_override
            w_eval = w
            if plan.dp_only:
                import dataclasses as _dc

                from repro.core.hardware import MeshSpec as _MS

                new = (_MS((mspec.axis_size("pod"), mspec.n_chips // mspec.axis_size("pod")),
                           ("pod", "data")) if "pod" in mspec.axes
                       else _MS((mspec.n_chips,), ("data",)))
                w_eval = _dc.replace(w, mesh=new)
            rt, mem = estimate_runtime(w_eval, plan), estimate_memory(w_eval, plan)
            w = w_eval
            rec["plan_feasible"] = mem.peak < hw.capacity_bytes()
        else:
            res = search(w, sp=sp)
            plan = res.plan
            rt, mem = res.runtime, res.memory
            rec["plan_feasible"] = res.feasible
        rec["plan"] = plan.describe() + (" dp" if plan.dp_only else "") + (
            " sp" if plan.seq_shard_acts else "")
        rec["modeled"] = {
            "t_iteration_s": rt.t_iteration,
            "tokens_per_s": rt.tokens_per_second,
            "peak_gb_per_chip": mem.peak / 1e9,
        }
        art = build_train_step(cfg, plan, mesh, shape)
        lowered = art.lower()
        flops_dev, bytes_dev = step_totals(w, plan)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * tokens / mspec.n_chips
    else:
        plan = plan_override or serve_plan(cfg, shape, mspec, hw)
        rec["plan"] = plan.describe()
        rec["modeled"] = serve_memory_estimate(cfg, shape, mspec, plan)
        w = None
        if shape.mode == "prefill":
            art = build_prefill_step(cfg, plan, mesh, shape)
            lowered = jax.jit(art.fn).lower(art.state_specs, art.batch_specs)
            w = build_workload(cfg, shape, mspec, hw)
            flops_dev, bytes_dev = serve_totals(w, plan)
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * cfg.active_param_count() * tokens / mspec.n_chips
        else:
            art = build_decode_step(cfg, plan, mesh, shape)
            lowered = art.lower(donate=True)
            from repro.core.chunks import chunk_inventory
            from repro.core.serve_plan import cache_bytes_per_device

            b_loc = shape.global_batch / mspec.zero_degree
            flops_dev = 2.0 * cfg.active_param_count() * b_loc / mspec.tp_degree
            bytes_dev = (
                sum(c.param_bytes for c in chunk_inventory(cfg)) / mspec.tp_degree
                + cache_bytes_per_device(cfg, shape, mspec)
            )
            model_flops = 2.0 * cfg.active_param_count() * shape.global_batch / mspec.n_chips

    lower_span.__exit__(None, None, None)
    rec["lower_s"] = round(lower_span.dur_s, 1)
    with tracer.span("dryrun.compile", arch=arch, shape=shape_name) as csp:
        compiled = lowered.compile()
    rec["compile_s"] = round(csp.dur_s, 1)

    mem = compiled.memory_analysis()
    rec["xla_memory"] = {
        "argument_gb": mem.argument_size_in_bytes / 1e9,
        "output_gb": mem.output_size_in_bytes / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "host_gb": (mem.host_argument_size_in_bytes + mem.host_temp_size_in_bytes) / 1e9,
        "alias_gb": mem.alias_size_in_bytes / 1e9,
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    if hlo_out:
        import zstandard

        with open(hlo_out, "wb") as f:
            f.write(zstandard.ZstdCompressor().compress(hlo.encode()))
    rep = RL.analyze(
        hlo=hlo,
        flops_per_chip=flops_dev,
        hbm_bytes_per_chip=bytes_dev,
        model_flops_per_chip=model_flops,
        hw=hw,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
    )
    rec["roofline"] = {
        "t_compute_s": rep.t_compute,
        "t_memory_s": rep.t_memory,
        "t_collective_s": rep.t_collective,
        "bottleneck": rep.bottleneck,
        "flops_per_chip": rep.flops_per_chip,
        "hbm_gb_per_chip": rep.hbm_bytes_per_chip / 1e9,
        "collective_gb_raw": rep.collective_bytes_raw / 1e9,
        "collective_gb_corrected": rep.collective_bytes_corrected / 1e9,
        "by_kind_gb": {k: v / 1e9 for k, v in rep.by_kind.items()},
        "model_flops_per_chip": rep.model_flops,
        "useful_flops_ratio": rep.useful_flops_ratio,
        "xla_flops_raw": rep.xla_flops_raw,
        "xla_bytes_raw": rep.xla_bytes_raw,
    }
    rec["ok"] = True
    return rec


def run_megatrain(arch: str, shape_name: str) -> dict:
    """MegaTrain demo (PAPERS.md): plan a 100B+ config with every chunk on
    the all-host optimizer tier — bf16 param/grad shards in HBM, fp32 Adam
    state + the update itself on host (autotuner.megatrain_plan) — then
    lower/compile it like any dryrun cell. Asserts the *planned* device
    footprint fits HardwareSpec.capacity_bytes() before spending the
    compile; the compiled record's host_gb shows the state tier landing in
    pinned host memory."""
    from repro.core import estimate_memory
    from repro.core.autotuner import megatrain_plan

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    assert shape.is_training, "--megatrain is a training-path demo"
    assert cfg.param_count() >= 100e9, (
        f"--megatrain demonstrates the 100B+ tier; {arch} is too small")
    mspec = mesh_spec(multi_pod=False)
    hw = TPU_V5E
    w = build_workload(cfg, shape, mspec, hw)
    plan = megatrain_plan(w)
    mem = estimate_memory(w, plan)
    assert mem.peak < hw.capacity_bytes(), (
        f"MegaTrain plan overflows the chip: planned {mem.peak / 1e9:.1f} GB "
        f">= capacity {hw.capacity_bytes() / 1e9:.1f} GB")
    rec = run_cell(arch, shape_name, False, plan_override=plan)
    rec["megatrain"] = {
        "planned_peak_gb": round(mem.peak / 1e9, 3),
        "capacity_gb": round(hw.capacity_bytes() / 1e9, 3),
        "model_states_gb": round(mem.model_states / 1e9, 3),
    }
    return rec


def cells(archs, shapes_filter=None):
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if shapes_filter and shape.name not in shapes_filter:
                continue
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sp", default="off", choices=["off", "on", "auto"])
    ap.add_argument("--megatrain", action="store_true",
                    help="one-cell MegaTrain demo: all-host optimizer tier "
                         "on a 100B+ model (default llama3-405b x train_4k)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(os.path.join(os.path.dirname(__file__), "../../../reports"))
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "dryrun_cells.jsonl")

    if args.megatrain:
        rec = run_megatrain(args.arch or "llama3-405b", args.shape or "train_4k")
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        mt, xm = rec["megatrain"], rec["xla_memory"]
        print(f"[dryrun] MEGATRAIN OK {rec['arch']} x {rec['shape']}: "
              f"plan [{rec['plan']}] planned {mt['planned_peak_gb']}GB "
              f"< capacity {mt['capacity_gb']}GB; compiled temp "
              f"{xm['temp_gb']:.2f}GB host {xm['host_gb']:.2f}GB")
        return 0
    done = set()
    if os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"], r.get("sp", "off")))
                except json.JSONDecodeError:
                    pass

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shape_filter = {args.shape} if args.shape else None
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    todo = [(a, s, mp) for a, s in cells(archs, shape_filter) for mp in meshes]
    print(f"[dryrun] {len(todo)} cells ({len(done)} already done)")
    failures = 0
    for arch, shape, mp in todo:
        key = (arch, shape, "multi" if mp else "single", args.sp)
        if key in done:
            continue
        tag = f"{arch} x {shape} x {key[2]}"
        try:
            rec = run_cell(arch, shape, mp, sp=args.sp)
            rl = rec["roofline"]
            print(f"[dryrun] OK  {tag}: bottleneck={rl['bottleneck']} "
                  f"comp={rl['t_compute_s']:.3f}s mem={rl['t_memory_s']:.3f}s "
                  f"coll={rl['t_collective_s']:.3f}s (compile {rec['compile_s']}s)",
                  flush=True)
        except Exception as e:
            failures += 1
            rec = {"arch": arch, "shape": shape, "mesh": key[2], "sp": args.sp,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {str(e)[:200]}", flush=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    print(f"[dryrun] complete, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
