"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = FLOPs_per_chip / (peak_FLOP/s)
    memory     = HBM_bytes_per_chip / HBM_bw
    collective = serialized collective bytes per chip / link_bw

FLOPs/bytes: ``compiled.cost_analysis()`` on the CPU backend does NOT multiply
while-loop body costs by trip count (verified empirically), so the analytic
oracle comes from the jaxpr profiler (trip-count aware) and cost_analysis is
reported as the raw reference. Collective bytes are parsed from the compiled
HLO with a call-graph walk that multiplies ops inside while bodies by their
trip counts (recovered from the loop-condition constants).

CPU-backend dtype caveat: XLA CPU upcasts every bf16 dot to fp32, which drags
weight all-gathers and some residuals to fp32 — 2x the bytes a TPU build
moves. We report both ``raw`` (exactly what this HLO says) and ``corrected``
(fp32 collective bytes halved — the bf16-native TPU number). See DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s32": 4,
               "u32": 4, "f32": 4, "f64": 8, "s64": 8, "u64": 8}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16384,53248]' -> bytes. Tuples: sum of elements."""
    total = 0
    for m in re.finditer(r"(pred|s8|u8|bf16|f16|s32|u32|f32|f64|s64|u64)\[([0-9,]*)\]", shape_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    nbytes: int  # payload (output for ag, input for rs, buffer for ar)
    dtype: str
    group_size: int
    computation: str
    multiplier: float = 1.0

    def wire_bytes(self) -> float:
        """Per-chip serialized bytes on the slowest link (ring algorithms)."""
        g = max(self.group_size, 1)
        if self.kind == "all-gather":
            return self.nbytes * (g - 1) / g
        if self.kind == "reduce-scatter":
            return self.nbytes * (g - 1) / g
        if self.kind == "all-reduce":
            return 2.0 * self.nbytes * (g - 1) / g
        if self.kind == "all-to-all":
            return self.nbytes * (g - 1) / g
        return float(self.nbytes)  # collective-permute


def _split_computations(hlo: str) -> dict[str, str]:
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        # header: `%name (params...) -> type {` — params may nest parens
        # (tuple-typed while bodies), so match greedily to the arrow
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", line)
        if m and line.rstrip().endswith("{"):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(2)
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
            if line.strip() == "}":
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count(cond_text: str) -> float:
    """Recover the trip count from a while condition (counter < constant)."""
    consts = [int(c) for c in re.findall(r"s32\[\]\s+constant\((\d+)\)", cond_text)]
    candidates = [c for c in consts if c > 1]
    return float(max(candidates)) if candidates else 1.0


def parse_collectives(hlo: str) -> list[CollectiveOp]:
    comps = _split_computations(hlo)
    entry = None
    for name, text in comps.items():
        if "ENTRY" in text.splitlines()[0]:
            entry = name
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k]))

    # call edges: while(body=, condition=), call/fusion(calls=), conditional
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        cur = order.pop(0)
        text = comps.get(cur, "")
        m_cur = mult.get(cur, 1.0)
        # while operands are usually tuple-typed — `while((s32[], ...) %t)` —
        # so the condition/body attributes are matched per line rather than
        # through the (nested-paren) operand list
        for line in text.splitlines():
            if not re.search(r"\bwhile\(", line):
                continue
            mc = re.search(r"condition=%?([\w.\-]+)", line)
            mb = re.search(r"body=%?([\w.\-]+)", line)
            if not (mc and mb):
                continue
            cond, body = mc.group(1), mb.group(1)
            tc = _trip_count(comps.get(cond, ""))
            mult[body] = mult.get(body, 0.0) + m_cur * tc
            if body not in seen:
                seen.add(body)
                order.append(body)
        for m in re.finditer(r"(?:calls|to_apply|branches)=\{?%?([\w.\-{},\s]+?)\}?[,\)]", text):
            for callee in re.findall(r"[\w.\-]+", m.group(1)):
                if callee in comps and callee != cur:
                    mult[callee] = mult.get(callee, 0.0) + m_cur
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    ops: list[CollectiveOp] = []
    for name, text in comps.items():
        m_comp = mult.get(name)
        if m_comp is None:
            continue
        for line in text.splitlines():
            mm = re.match(r"\s*%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", line)
            if not mm:
                continue
            if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)-done", line):
                continue
            shape_str, kind = mm.groups()
            nbytes = _shape_bytes(shape_str)
            if kind == "all-gather":
                pass  # output shape == full gathered payload
            dts = re.findall(r"(pred|s8|u8|bf16|f16|f32|s32|u32|f64)\[", shape_str)
            dtype = dts[0] if dts else "f32"
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if gm:
                group_size = int(gm.group(2))
            else:
                gm2 = re.search(r"replica_groups=\{\{([^}]*)\}", line)
                group_size = len(gm2.group(1).split(",")) if gm2 else 1
            ops.append(CollectiveOp(kind, nbytes, dtype, group_size, name, m_comp))
    return ops


@dataclasses.dataclass
class RooflineReport:
    flops_per_chip: float  # analytic, trip-count aware
    hbm_bytes_per_chip: float
    collective_bytes_raw: float  # per chip, serialized, as compiled (CPU fp32)
    collective_bytes_corrected: float  # fp32->bf16 corrected
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float  # 6*N*D (dense) / 6*N_active*D (MoE)
    useful_flops_ratio: float
    by_kind: dict[str, float]
    xla_flops_raw: float = 0.0
    xla_bytes_raw: float = 0.0

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d["by_kind"] = {k: round(v / 1e9, 3) for k, v in self.by_kind.items()}
        return d


def analyze(
    *,
    hlo: str,
    flops_per_chip: float,
    hbm_bytes_per_chip: float,
    model_flops_per_chip: float,
    hw,
    xla_flops: float = 0.0,
    xla_bytes: float = 0.0,
    dtype_correction: bool = True,
) -> RooflineReport:
    ops = parse_collectives(hlo)
    raw = sum(o.wire_bytes() * o.multiplier for o in ops)
    corrected = sum(
        o.wire_bytes() * o.multiplier * (0.5 if (dtype_correction and o.dtype == "f32") else 1.0)
        for o in ops
    )
    by_kind: dict[str, float] = {}
    for o in ops:
        by_kind[o.kind] = by_kind.get(o.kind, 0.0) + o.wire_bytes() * o.multiplier

    t_comp = flops_per_chip / hw.peak_flops
    t_mem = hbm_bytes_per_chip / hw.hbm_bw
    t_coll = corrected / hw.ici_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    return RooflineReport(
        flops_per_chip=flops_per_chip,
        hbm_bytes_per_chip=hbm_bytes_per_chip,
        collective_bytes_raw=raw,
        collective_bytes_corrected=corrected,
        t_compute=t_comp,
        t_memory=t_mem,
        t_collective=t_coll,
        bottleneck=max(terms, key=terms.get),
        model_flops=model_flops_per_chip,
        useful_flops_ratio=model_flops_per_chip / flops_per_chip if flops_per_chip else 0.0,
        by_kind=by_kind,
        xla_flops_raw=xla_flops,
        xla_bytes_raw=xla_bytes,
    )
