"""MemoryPlan: the compact configuration space ProTrain searches (§3.3).

The paper's tunables {n_persist, n_buffer, n_swap, n_checkpoint} plus the
TPU-hierarchy extension ``n_host`` (non-persistent chunks whose shards live in
host memory rather than HBM — the analogue of the paper's CPU offload of
parameters/optimizer states, generalized because a v5e chip has only 16 GB)
and ``microbatch`` (gradient accumulation splits, which the memory model needs
to reason about activation footprints at large global batches).

Chunk i (execution order) is treated as:
  i <  n_persist                  -> persistent: replicated over ZeRO axes
  n_persist <= i < N - n_host     -> ZeRO-sharded, shards resident in HBM
  i >= N - n_host                 -> ZeRO-sharded, shards resident in host mem
Block b (one per chunk; chunk == superblock == transformer block group):
  b <  n_swap                     -> "swap": block-interior activations are
                                     offloaded to host (jax.checkpoint offload
                                     policy); the block boundary (the scan
                                     carry) stays on device — a documented TPU
                                     adaptation: XLA scan AD owns the carries
  n_swap <= b < n_swap + n_ckpt   -> gradient checkpointing (remat)
  otherwise                       -> unoptimized (keep activations)
The scalar {n_swap, n_checkpoint} boundary is a *lowering*: it describes the
uniform prefix layouts the paper searches. ``act_policies`` generalizes it to
an explicit per-block policy vector over
{none|checkpoint|swap|compress8|compress16} (aliases keep->none,
remat->checkpoint accepted), making the activation axis a searched dimension
like placement — the compress entries save activations through the
quantize-on-save custom_vjp (models/model.compress_act) instead of holding
full precision or recomputing. When ``act_policies`` is None every existing
plan keeps its scalar-knob semantics unchanged.
Buffers: the last ``n_buffer`` non-persistent chunks keep their *gathered*
weights live from forward to backward (no re-gather in BWD) — the analogue of
chunk-buffer reuse; the backward pass visits those chunks first, which is
exactly the paper's motivation for placing persistent chunks at the front.

Swap blocks are placed earliest (paper Fig. 2: more time to overlap), then
checkpoint blocks, then unoptimized blocks last so their activations are
consumed first in BWD.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    n_chunks: int  # N_chunk (model-state chunks == superblocks + embed/head)
    n_blocks: int  # N_block (activation blocks == superblocks)
    n_persist: int = 0
    n_buffer: int = 0
    n_swap: int = 0
    n_checkpoint: int = 0
    n_host: int = 0  # non-persistent chunks offloaded to host memory
    microbatch: int = 1  # gradient-accumulation splits of the global batch
    host_optimizer: bool = True  # host chunks update off-device (CPU-Adam analogue)
    zero1_persistent: bool = False  # beyond-paper: shard opt state of persistent chunks
    # beyond-paper: shard block-boundary activations over the TP axis
    # (Megatron-style sequence parallelism); the paper-faithful baseline keeps
    # boundaries replicated across TP like its GPU implementation does.
    seq_shard_acts: bool = False
    # beyond-paper: repurpose the model axis as an extra data axis (weights
    # replicated across it, batch sharded over it). Kills the Megatron TP
    # activation all-reduces that dominate small models on a fixed
    # (data, model) production mesh. Requires global_batch % n_chips == 0.
    dp_only: bool = False
    # beyond-paper: checkpoint granularity — remat regions of `ckpt_group`
    # consecutive layers instead of one. Saves 1/g of the boundary
    # activations at the cost of g-layer recompute working sets (the
    # classic sqrt(n) rematerialization trade, Chen et al. 2016).
    ckpt_group: int = 1
    # host-chunk layout: True = paper-faithful full offload (params + states on
    # host; gathers ride the host link every microbatch). False = ZeRO-Offload
    # split: bf16 param/grad shards stay in HBM (gathers ride ICI), only the
    # fp32 optimizer states live on host and round-trip once per step.
    host_params: bool = True
    # beyond-paper: gradient-sync wire compression (repro.dist.collectives).
    # "none" keeps XLA's native reduction; "bf16" forces a bf16 wire format;
    # "int8_ef" quantizes to int8 with error-feedback residuals carried in the
    # train state (fp32 per-param, accounted by the memory model).
    grad_compress: str = "none"
    # who owns the gradient reduction (see docs/architecture.md):
    #   "xla"    — GSPMD inserts the reduce; grad_compress applies the wire
    #              *numerics* to the already-reduced grads (wire bytes
    #              unchanged — calibration measures factor ~1.0);
    #   "manual" — the step builder wraps loss/grad in a shard_map over the
    #              batch axes and owns the sync: local grads are quantized and
    #              the compressed payload crosses the wire (real byte
    #              savings). Replicated layouts sync DDP-style (compressed
    #              all-gather); ZeRO-sharded layouts reduce-scatter the
    #              compressed payload to shard owners; see manual_sync_kind().
    sync_mode: str = "xla"
    # manual-sync ZeRO dataflow for sharded plans (ignored otherwise):
    #   3 — lazy per-chunk gather: each chunk's bf16 params are all-gathered
    #       just-in-time inside the layer scan through a custom-vjp gather
    #       whose transpose IS the compressed reduce-scatter, so full params
    #       never coexist and n_buffer keeps its xla-path meaning (buffered
    #       chunks hold gathered weights FWD->BWD, unbuffered ones re-gather
    #       in BWD);
    #   2 — legacy up-front gather: full bf16 params live for the whole step
    #       (ZeRO-2-style memory), no re-gathers.
    zero_stage: int = 3
    # comm/compute overlap on the manual path (docs/cost_model.md §2):
    #   True  — the step builder pipelines the zero3 lazy gathers (chunk k+1's
    #           all-gather issued during chunk k's matmuls, barrier-ordered
    #           like serve/paging's double buffer — needs n_buffer >= 2, see
    #           gather_prefetch_depth), defers each microbatch's gradient
    #           accumulate so the reduce-scatter overlaps the next backward,
    #           and issues host param fetches before the layer scan; the cost
    #           model prices per-chunk comm as max(compute, comm);
    #   False — everything runs inline and the cost model prices comm serially
    #           (sum) — the pre-overlap baseline the benchmarks compare to.
    # The xla path ignores this knob: GSPMD's scheduler owns overlap there.
    overlap: bool = True
    # Per-block activation policy vector (tentpole of the adaptive-activation
    # PR): entry b in {"none","checkpoint","swap","compress8","compress16"}
    # decides what block b saves for backward. None (default) lowers the
    # scalar {n_swap, n_checkpoint} prefix knobs to the uniform vector via
    # block_policy(), so every pre-vector plan is unchanged. Aliases
    # "keep"->"none" and "remat"->"checkpoint" are normalized on construction.
    # Setting a vector requires the scalar knobs stay 0 (one source of truth).
    act_policies: tuple[str, ...] | None = None

    #: policies block_policy() may return / act_policies may contain
    ACT_POLICIES = ("none", "checkpoint", "swap", "compress8", "compress16")
    _ACT_ALIASES = {"keep": "none", "remat": "checkpoint"}

    @property
    def gather_prefetch_depth(self) -> int:
        """Gather buffers the zero3 prefetch pipeline may hold in flight.

        2 (double-buffered: prefetch + execute) when overlap is on, the plan
        syncs manually at zero_stage 3, and ``n_buffer >= 2`` gives the remat
        policy room to keep both gathered chunks live; 1 (serial, gather at
        point of use) otherwise — the documented serial fallback for
        ``n_buffer < 2``.
        """
        if (self.overlap and self.sync_mode == "manual"
                and self.zero_stage == 3 and self.n_buffer >= 2):
            return 2
        return 1

    def __post_init__(self):
        assert 0 <= self.n_persist <= self.n_chunks
        assert 0 <= self.n_buffer <= self.n_chunks - self.n_persist
        # Training plans bound n_host by the non-persistent chunk count.
        # Serving plans overload n_host as "KV-cache pages offloaded to host"
        # (core/serve_plan.py), which is legal alongside n_persist == n_chunks
        # because chunk_placement checks persistence first — the weight stack
        # stays persistent while the page count rides in n_host.
        assert self.n_host >= 0
        assert (self.n_host <= self.n_chunks - self.n_persist
                or self.n_persist == self.n_chunks)
        assert 0 <= self.n_swap + self.n_checkpoint <= self.n_blocks
        assert self.microbatch >= 1
        assert self.grad_compress in ("none", "bf16", "int8_ef"), self.grad_compress
        assert self.sync_mode in ("xla", "manual"), self.sync_mode
        assert self.zero_stage in (2, 3), self.zero_stage
        if self.act_policies is not None:
            pols = tuple(self._ACT_ALIASES.get(p, p) for p in self.act_policies)
            object.__setattr__(self, "act_policies", pols)
            assert len(pols) == self.n_blocks, (len(pols), self.n_blocks)
            for p in pols:
                assert p in self.ACT_POLICIES, p
            # the vector replaces the scalar prefix knobs — both set is
            # ambiguous, so the constructor refuses it
            assert self.n_swap == 0 and self.n_checkpoint == 0, (
                "act_policies replaces n_swap/n_checkpoint; keep them 0")

    # ---- n_host facade ----------------------------------------------------
    # ``n_host`` is overloaded: training plans count host-offloaded parameter
    # chunks; serve plans (n_persist == n_chunks, core/serve_plan.py) count
    # cold KV-cache pages. These accessors are the canonical reads — call
    # sites that use them survive the planned split of the field into
    # per-resource host budgets (ROADMAP) without edits.
    @property
    def host_param_chunks(self) -> int:
        """Parameter chunks whose shards live in host memory (0 for serve
        plans, where n_host counts cache pages instead)."""
        return self.n_host if self.n_persist < self.n_chunks else 0

    @property
    def cold_kv_pages(self) -> int:
        """Host-resident KV-cache pages of a serve plan (0 for training
        plans, where n_host counts parameter chunks instead)."""
        return self.n_host if self.n_persist == self.n_chunks else 0

    # ---- manual gradient sync eligibility ---------------------------------
    def manual_sync_kind(self, tp_degree: int = 1) -> str | None:
        """Which manual shard_map sync pipeline this plan lowers to, if any.

        Returns:
          * ``"ddp"``   — fully-replicated layout: the body computes per-device
            gradients with replicated parameter specs and syncs them with a
            compressed all-gather over the batch axes (DDP-style).
          * ``"zero2"`` — ZeRO-sharded layout, ``zero_stage=2``: the body
            gathers the bf16 param shards up front (full bf16 params live for
            the step, fp32 optimizer states and the synced gradient stay
            shard-resident), then reduce-scatters the compressed local
            gradients so each device owns its shard's reduced gradient and
            updates it in place.
          * ``"zero3"`` — ZeRO-sharded layout, ``zero_stage=3`` (default):
            same shard-resident state and compressed reduce-scatter, but each
            chunk's bf16 params are gathered lazily inside the layer scan via
            a custom-vjp all-gather whose transpose is the reduce-scatter —
            full params never coexist, restoring true ZeRO-3 param memory;
            ``n_buffer`` decides which chunks keep gathered weights FWD->BWD.
          * ``None``    — cannot lower manually; ``sync_mode="manual"`` raises.

        Shared requirements (all kinds):

          * no activation swapping (host-offload remat policies reference
            memory kinds that cannot be named inside a shard_map body);
          * no host-resident chunks (same memory-kind constraint).

        Kind-specific:

          * "ddp" additionally needs replicated fp32 optimizer states (no
            zero1_persistent) and tp_degree == 1 unless dp_only repurposes
            the model axis as a batch axis;
          * "zero2"/"zero3" need tp_degree == 1 outright (with a real model
            axis the ZeRO shard axes and the batch/sync axes differ — dp_only
            shards the batch over the model axis too, but parameters still
            shard over the ZeRO axes only, so the reduce-scatter owner
            coordinate would not match the storage layout) and no
            zero1_persistent (persistent chunks keep replicated updates).

        Ineligible plans keep ``sync_mode="xla"`` semantics; the autotuner
        only proposes "manual" for plans with a non-None kind.
        """
        if ("swap" in self.block_policies() or self.host_param_chunks > 0
                or self.zero1_persistent):
            return None
        if self.n_persist == self.n_chunks:
            return "ddp" if (tp_degree == 1 or self.dp_only) else None
        if tp_degree != 1:
            return None
        return "zero3" if self.zero_stage == 3 else "zero2"

    def manual_sync_ok(self, tp_degree: int = 1) -> bool:
        """True when the plan lowers manually at all (any kind)."""
        return self.manual_sync_kind(tp_degree) is not None

    # ---- block policy ----------------------------------------------------
    def block_policy(self, b: int) -> str:
        if self.act_policies is not None:
            return self.act_policies[b]
        if b < self.n_swap:
            return "swap"
        if b < self.n_swap + self.n_checkpoint:
            return "checkpoint"
        return "none"

    def block_policies(self) -> list[str]:
        return [self.block_policy(b) for b in range(self.n_blocks)]

    def compressed_blocks(self) -> int:
        """How many blocks save through the quantize-on-save seam."""
        return sum(p in ("compress8", "compress16") for p in self.block_policies())

    # ---- chunk placement ---------------------------------------------------
    def chunk_placement(self, i: int) -> str:
        """persist | hbm | host, for chunk i in execution order."""
        if i < self.n_persist:
            return "persist"
        if i >= self.n_chunks - self.host_param_chunks:
            return "host"
        return "hbm"

    def chunk_buffered(self, i: int) -> bool:
        """Gathered weights of chunk i kept live FWD->BWD?"""
        if self.chunk_placement(i) == "persist":
            return True  # persistent chunks are always resident
        return i >= self.n_chunks - self.n_buffer

    def describe(self) -> str:
        comp = "" if self.grad_compress == "none" else f" comm={self.grad_compress}"
        if self.sync_mode != "xla":
            comp += f" sync={self.sync_mode}"
            comp += f" zstage={self.zero_stage}"
            comp += f" overlap={'on' if self.overlap else 'off'}"
        if self.ckpt_group != 1:
            comp += f" ckptg={self.ckpt_group}"
        if self.act_policies is not None:
            runs, prev = [], None
            for p in self.act_policies:
                if prev is not None and p == prev[0]:
                    prev[1] += 1
                else:
                    prev = [p, 1]
                    runs.append(prev)
            comp += " acts=" + ",".join(
                p if n == 1 else f"{p}x{n}" for p, n in runs)
        return (
            f"persist={self.n_persist}/{self.n_chunks} buffer={self.n_buffer} "
            f"host={self.n_host} swap={self.n_swap} ckpt={self.n_checkpoint} "
            f"ubatch={self.microbatch}{comp}"
        )


def fully_resident_plan(n_chunks: int, n_blocks: int) -> MemoryPlan:
    """Everything persistent, no remat/swap — the small-model fast path."""
    return MemoryPlan(n_chunks=n_chunks, n_blocks=n_blocks, n_persist=n_chunks, n_host=0)


def fsdp_style_plan(n_chunks: int, n_blocks: int, checkpoint_all: bool = True) -> MemoryPlan:
    """Paper baseline: FSDP = everything sharded, checkpoint all-or-nothing."""
    return MemoryPlan(
        n_chunks=n_chunks,
        n_blocks=n_blocks,
        n_persist=0,
        n_buffer=0,
        n_checkpoint=n_blocks if checkpoint_all else 0,
    )
