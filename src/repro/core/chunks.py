"""Hierarchical chunk management (§3.1.1, §B.1).

Chunks are built in *execution order* — embedding (+ encoder) first, then one
chunk per superblock repeat, then the head — which is precisely the paper's
fix for the ping-pong access pattern of declaration-order chunking. One
transformer superblock per chunk matches §B.1 ("groups parameters from the
same transformer block into one chunk").

``chunk_size_search`` reproduces the paper's fixed-size chunk search (grid
search minimizing padding waste) — used by benchmarks and tests; the planner
itself uses block-aligned chunks.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import ParamDef

BYTES = {"bfloat16": 2, "float32": 4, "float16": 2}


def _tree_param_bytes(defs) -> tuple[int, int]:
    """(total param count, total param bytes) for a ParamDef pytree."""
    leaves = [l for l in jax.tree.leaves(defs) if isinstance(l, ParamDef)]
    count = sum(int(np.prod(d.shape)) for d in leaves)
    nbytes = sum(int(np.prod(d.shape)) * BYTES[d.dtype] for d in leaves)
    return count, nbytes


@dataclasses.dataclass(frozen=True)
class ChunkInfo:
    index: int  # execution order
    name: str
    param_count: int
    param_bytes: int  # compute-dtype bytes
    is_block: bool  # True for superblock chunks (have activations/FLOPs)
    block_index: int = -1  # which activation block this chunk backs

    @property
    def grad_bytes(self) -> int:
        return self.param_bytes  # grads kept in compute dtype

    @property
    def optim_bytes(self) -> int:
        # fp32 master + Adam m + v (mixed-precision training, paper §2)
        return 12 * self.param_count


def chunk_inventory(cfg: ModelConfig) -> list[ChunkInfo]:
    """Execution-order chunks: [embed(+encoder)] [superblock x R] [head]."""
    defs = M.param_defs(cfg)
    chunks: list[ChunkInfo] = []
    r = M.num_repeats(cfg)

    front = {"embed": defs["embed"]}
    if "encoder" in defs:
        front["encoder"] = defs["encoder"]
    cnt, nbytes = _tree_param_bytes(front)
    chunks.append(ChunkInfo(0, "embed", cnt, nbytes, is_block=False))

    # one chunk per superblock repeat; stacked defs are divided evenly by R
    cnt_all, bytes_all = _tree_param_bytes(defs["blocks"])
    per_cnt, per_bytes = cnt_all // r, bytes_all // r
    for i in range(r):
        chunks.append(
            ChunkInfo(1 + i, f"superblock{i}", per_cnt, per_bytes, is_block=True, block_index=i)
        )

    tail = {"final_norm": defs["final_norm"]}
    if "head" in defs:
        tail["head"] = defs["head"]
    cnt, nbytes = _tree_param_bytes(tail)
    chunks.append(ChunkInfo(1 + r, "head", cnt, nbytes, is_block=False))
    return chunks


def total_param_count(chunks: list[ChunkInfo]) -> int:
    return sum(c.param_count for c in chunks)


def model_state_bytes(chunks: list[ChunkInfo]) -> int:
    """Full mixed-precision model states: ~16 bytes/param (paper §1)."""
    return sum(c.param_bytes + c.grad_bytes + c.optim_bytes for c in chunks)


# ---------------------------------------------------------------------------
# §B.1 fixed-size chunk search (padding-waste minimization)
# ---------------------------------------------------------------------------
def pack_into_chunks(param_sizes: list[int], chunk_size: int) -> list[list[int]]:
    """Greedy packing in execution order; params never span chunk boundaries.

    Params larger than the chunk get a dedicated (oversized) chunk, as in
    Colossal-AI's chunk manager.
    """
    chunks: list[list[int]] = []
    cur: list[int] = []
    cur_sz = 0
    for s in param_sizes:
        if s >= chunk_size:
            if cur:
                chunks.append(cur)
                cur, cur_sz = [], 0
            chunks.append([s])
            continue
        if cur_sz + s > chunk_size:
            chunks.append(cur)
            cur, cur_sz = [], 0
        cur.append(s)
        cur_sz += s
    if cur:
        chunks.append(cur)
    return chunks


def chunk_waste(param_sizes: list[int], chunk_size: int) -> int:
    """Total padding bytes when packing params into fixed-size chunks.

    Oversized (dedicated) chunks are exact-fit: ``max(chunk_size, total)``
    equals ``total`` whenever ``total >= chunk_size``, so they contribute
    zero padding."""
    waste = 0
    for chunk in pack_into_chunks(param_sizes, chunk_size):
        total = sum(chunk)
        waste += max(chunk_size, total) - total
    return waste


def chunk_size_search(
    param_sizes: list[int],
    candidates: list[int] | None = None,
) -> tuple[int, int]:
    """Grid search over chunk sizes minimizing simulated waste (§B.1).

    Returns (best_chunk_size, waste_bytes). Ties prefer larger chunks
    (better transfer efficiency).
    """
    if candidates is None:
        candidates = [1 << p for p in range(20, 29)]  # 1 MiB .. 256 MiB elems
    best, best_waste = candidates[0], None
    for c in candidates:
        w = chunk_waste(param_sizes, c)
        if best_waste is None or w < best_waste or (w == best_waste and c > best):
            best, best_waste = c, w
    return best, int(best_waste)
