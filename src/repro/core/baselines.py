"""Baseline planners emulating the frameworks the paper compares against.

The paper benchmarks ProTrain vs DeepSpeed (ZeRO-3 + offload, threshold
tuning), Colossal-AI (Gemini chunk manager, static placement), and FSDP
(flat-param ZeRO-3, all-or-nothing checkpointing). We reproduce each as a
*fixed policy* in our plan space so the benchmark harness can compare them
through the same cost models — the apples-to-apples adaptation of the paper's
framework comparison (the mechanisms, not the marketing).
"""
from __future__ import annotations

from repro.core.cost_model import Workload, estimate_memory
from repro.core.plan import MemoryPlan


def fsdp_plan(w: Workload, capacity: float, offload: bool = False) -> MemoryPlan:
    """FSDP: everything sharded, no persistence/buffering, checkpointing is
    all-or-nothing, optional uniform CPU offload."""
    nc, nb = w.n_chunks, w.n_blocks
    for ckpt_all in (False, True):
        for host in ([0] if not offload else [0, nc]):
            plan = MemoryPlan(nc, nb, n_checkpoint=nb if ckpt_all else 0, n_host=host)
            if estimate_memory(w, plan).peak < capacity:
                return plan
    return MemoryPlan(nc, nb, n_checkpoint=nb, n_host=nc if offload else 0)


def deepspeed_plan(w: Workload, capacity: float) -> MemoryPlan:
    """DeepSpeed ZeRO-3 + offload: params/optimizer offloaded wholesale,
    checkpointing all blocks, a threshold-style live-parameter window (we
    model it as a small fixed buffer count — the paper's critique is exactly
    that these thresholds are static)."""
    nc, nb = w.n_chunks, w.n_blocks
    plan = MemoryPlan(nc, nb, n_checkpoint=nb, n_host=nc, n_buffer=0)
    return plan


def colossal_plan(w: Workload, capacity: float) -> MemoryPlan:
    """Colossal-AI Gemini: chunk-based ZeRO-3, static placement — as many
    chunk shards kept in device memory as fit (no execution-order awareness,
    no buffering), checkpointing all blocks."""
    nc, nb = w.n_chunks, w.n_blocks
    # static placement: fill device with persistent chunks from the *front in
    # declaration order* (== execution order here), remainder to host
    lo, hi = 0, nc
    best = MemoryPlan(nc, nb, n_checkpoint=nb, n_host=nc)
    while lo <= hi:
        mid = (lo + hi) // 2
        plan = MemoryPlan(nc, nb, n_persist=0, n_host=nc - mid, n_checkpoint=nb)
        if estimate_memory(w, plan).peak < capacity:
            best = plan
            lo = mid + 1
        else:
            hi = mid - 1
    return best


BASELINES = {
    "fsdp": lambda w, cap: fsdp_plan(w, cap),
    "fsdp_offload": lambda w, cap: fsdp_plan(w, cap, offload=True),
    "deepspeed": deepspeed_plan,
    "colossalai": colossal_plan,
}
