"""Automatic memory management (§3.3): constrained configuration search.

    min_{configs} T_iteration   s.t.   M_peak < M_capacity        (Eq. 1)

over configs = {n_persist, n_buffer, n_swap, n_checkpoint} (+ TPU extensions
n_host, microbatch). Pruning mirrors the paper:

  * n_swap is restricted to the bandwidth-feasible set (swap must drain within
    the forward compute window — the N_interval constraint);
  * memory is monotone in n_persist/n_buffer (and anti-monotone in n_host),
    so instead of enumerating we binary-search the largest feasible values —
    the monotone equivalent of "evaluate in increasing memory order and
    discard over-capacity configs early";
  * runtime is monotone-decreasing in n_persist and n_buffer at fixed
    (n_swap, n_checkpoint, microbatch), so maximizing them is optimal per cell.

The search is exhaustive over the remaining axes. All evaluations are analytic
(cost_model) — no training iterations are run, matching the paper's 0.06 s
search overhead claim.

Beyond-paper axes (docs/cost_model.md documents every knob and its units):

  * ``compress`` — gradient-sync wire compression ("auto" by default now that
    the wire factors are calibrated against measured dry-run bytes; see
    benchmarks/calibrate_wire.py and cost_model.wire_factor);
  * per-block activation policies — after the scalar search settles the
    placement axes, ``search_act_policies`` greedily refines the winning
    cell's activation vector over {keep, compress8, remat} ("compress until
    feasible, then buy back latency"); see ACT_LADDER;
  * ``sync`` — who owns the gradient reduction: "xla" (GSPMD's reduce,
    compression is numerics-only) or "manual" (shard_map sync with the
    compressed payload on the wire: DDP-style compressed all-gather for
    fully-replicated layouts, compressed reduce-scatter for ZeRO-sharded
    ones). "manual" candidates are only emitted for plans with a non-None
    ``MemoryPlan.manual_sync_kind`` — exactly what the step builder can
    lower. ZeRO-sharded manual cells emit both dataflows: "zero3" (lazy
    per-chunk gather, true ZeRO-3 param memory — n_persist x n_buffer are
    binary-searched like the xla cells) and "zero2" (up-front gather, no
    re-gathers, ZeRO-2 memory), letting the cost models arbitrate the
    memory-vs-regather trade per workload.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

from repro.core.cost_model import (
    MemoryBreakdown,
    RuntimeBreakdown,
    Workload,
    estimate_memory,
    estimate_runtime,
)
from repro.core.plan import MemoryPlan


@dataclasses.dataclass
class SearchResult:
    plan: MemoryPlan
    runtime: RuntimeBreakdown
    memory: MemoryBreakdown
    evaluated: int
    search_seconds: float
    feasible: bool


def _fits(w: Workload, plan: MemoryPlan, capacity: float) -> bool:
    return estimate_memory(w, plan).peak < capacity


def _max_feasible(lo: int, hi: int, pred) -> int:
    """Largest v in [lo, hi] with pred(v), assuming pred monotone-decreasing.
    Returns lo-1 if none."""
    if not pred(lo):
        return lo - 1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if pred(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def _grid(n: int, max_points: int = 9) -> list[int]:
    if n <= max_points:
        return list(range(n + 1))
    step = max(1, n // (max_points - 1))
    vals = sorted(set(list(range(0, n + 1, step)) + [n]))
    return vals


# The searched activation-policy ladder, ordered memory-down / latency-up:
# keep everything -> quantize the save sites to int8 -> full remat.
# ``compress16`` is a lattice point the cost model prices but the search
# skips: it moves twice compress8's bytes for the same partial-recompute
# fraction, so it is dominated in (time, memory) — it exists for
# numerics-conservative hand-written plans, not for the optimizer.
ACT_LADDER = ("none", "compress8", "checkpoint")


def search_act_policies(
    w: Workload,
    base: MemoryPlan,
    capacity_bytes: float | None = None,
) -> SearchResult:
    """Greedy per-block activation-policy search under the memory budget.

    The classic "compress until feasible, then buy back latency" sweep over
    the per-block policy vector (MemoryPlan.act_policies), starting from
    ``base``'s lowered vector with every non-swap block on the ladder
    (swap blocks are pinned — their trade is the host link, owned by the
    scalar search):

      phase 1 (degrade, front-to-back — mirroring the n_checkpoint prefix):
        step blocks none -> compress8, then compress8 -> checkpoint, one
        block at a time, stopping at the first feasible vector;
      phase 2 (buy back, back-to-front): upgrade one rung at a time wherever
        the result still fits and the modeled step time does not regress,
        sweeping until a full pass changes nothing.

    Fully deterministic: no tie randomization, fixed sweep orders. Returns
    the vector plan (feasible=False when even remat-all overflows)."""
    t0 = time.time()
    capacity = (capacity_bytes if capacity_bytes is not None
                else w.hw.capacity_bytes())
    vec = list(base.block_policies())
    evaluated = 0

    def mk(v) -> MemoryPlan:
        return dataclasses.replace(
            base, n_swap=0, n_checkpoint=0, act_policies=tuple(v))

    def fits(v) -> bool:
        nonlocal evaluated
        evaluated += 1
        return estimate_memory(w, mk(v)).peak < capacity

    feasible = fits(vec)
    for target in ACT_LADDER[1:]:
        if feasible:
            break
        for b in range(len(vec)):
            if feasible:
                break
            cur = vec[b]
            if (cur not in ACT_LADDER
                    or ACT_LADDER.index(cur) >= ACT_LADDER.index(target)):
                continue
            vec[b] = target
            feasible = fits(vec)

    if feasible:
        best_rt = estimate_runtime(w, mk(vec)).t_iteration
        changed = True
        while changed:
            changed = False
            for b in range(len(vec) - 1, -1, -1):
                cur = vec[b]
                if cur not in ACT_LADDER or cur == "none":
                    continue
                trial = list(vec)
                trial[b] = ACT_LADDER[ACT_LADDER.index(cur) - 1]
                if not fits(trial):
                    continue
                rt = estimate_runtime(w, mk(trial)).t_iteration
                if rt <= best_rt:
                    vec, best_rt, changed = trial, rt, True

    plan = mk(vec)
    res = SearchResult(plan, estimate_runtime(w, plan),
                       estimate_memory(w, plan), evaluated,
                       time.time() - t0, feasible)
    return res


def megatrain_plan(w: Workload, checkpoint_all: bool = True) -> MemoryPlan:
    """MegaTrain-style all-host optimizer tier (PAPERS.md).

    Every chunk rides the ZeRO-Offload split: bf16 param/grad shards stay in
    HBM (gathers ride ICI, not the host link), while the fp32 Adam moments,
    master copy, and the update itself live on host (``host_optimizer`` —
    the existing ``adam_update(host=...)`` tuple in train/step_builder).
    With remat-all this is the minimal-state-footprint plan short of
    activation swapping; the activation axis is then closed by taking the
    smallest gradient-accumulation split (and, only if that is not enough,
    sequence-sharding the boundaries) that fits — which is how 100B-class
    configs plan onto 16 GB chips (launch/dryrun.py --megatrain demonstrates
    and asserts the fit). Returns the most frugal candidate even when
    nothing fits; callers check estimate_memory themselves."""
    nc, nb = w.n_chunks, w.n_blocks
    seqs = max(int(w.seqs_per_device), 1)
    mbs = [m for m in (1, 2, 4, 8, 16, 32, 64, 128, 256) if m <= seqs]
    plan = None
    for sp in (False, True):
        for mb in mbs:
            plan = MemoryPlan(
                nc, nb, n_persist=0, n_host=nc, host_params=False,
                host_optimizer=True,
                n_checkpoint=nb if checkpoint_all else 0,
                microbatch=mb, seq_shard_acts=sp,
            )
            if _fits(w, plan, w.hw.capacity_bytes()):
                return plan
    return plan


def search(
    w: Workload,
    capacity_bytes: float | None = None,
    *,
    microbatches: tuple[int, ...] = (1, 2, 4, 8, 16),
    allow_host: bool = True,
    allow_swap: bool = True,
    max_checkpoint_points: int = 9,
    sp: str = "off",  # "off" (paper-faithful) | "on" | "auto" (beyond-paper)
    dp: str = "off",  # "off" | "auto": also consider dp_only (model axis -> data)
    # int8+EF gradient-sync wire compression; "auto" by default — the wire
    # factors are calibrated (cost_model.wire_factor), so weighing the knob
    # costs nothing and the search is honest about when compression pays.
    compress: str = "auto",  # "off" | "on" | "auto"
    sync: str = "auto",  # "xla" | "manual" | "auto": who owns the grad reduce
    # comm/compute overlap on the manual path: candidates are priced with the
    # prefetch/deferred-accumulation pipeline on (plan.overlap). Pass False to
    # score the serial manual schedule (PR-6 baseline) instead.
    overlap: bool = True,
) -> SearchResult:
    """Find the fastest plan fitting in per-chip memory."""
    t0 = time.time()
    capacity = capacity_bytes if capacity_bytes is not None else w.hw.capacity_bytes()
    nc, nb = w.n_chunks, w.n_blocks
    best: SearchResult | None = None
    evaluated = 0

    sp_vals = {"off": (False,), "on": (True,), "auto": (False, True)}[sp]
    dp_vals = {"off": (False,), "on": (True,), "auto": (False, True)}[dp]
    gc_only = {"off": ("none",), "on": ("int8_ef",), "auto": ("none", "int8_ef")}[compress]
    sync_only = {"xla": ("xla",), "manual": ("manual",), "auto": ("xla", "manual")}[sync]
    # (grad_compress, sync_mode) combos: manual sync without compression has
    # no upside over XLA's native reduce, so it is never proposed
    gc_vals = tuple(
        (gc, sm) for gc in gc_only for sm in sync_only
        if not (gc == "none" and sm == "manual")
    )
    if not gc_vals:
        raise ValueError(
            f"search(compress={compress!r}, sync={sync!r}) leaves nothing to "
            "search: manual sync exists to put compressed payloads on the "
            "wire, so it requires compress != 'off'"
        )

    def dp_view(wl: Workload) -> Workload:
        """Evaluate dp_only plans under a mesh where the model axis has been
        folded into the data axis (tp=1, zero=n_chips_per_pod_axis)."""
        from repro.core.hardware import MeshSpec

        m = wl.mesh
        if "pod" in m.axes:
            new = MeshSpec((m.axis_size("pod"), m.n_chips // m.axis_size("pod")),
                           ("pod", "data"))
        else:
            new = MeshSpec((m.n_chips,), ("data",))
        return dataclasses.replace(wl, mesh=new)

    real_tp = w.mesh.tp_degree  # pre-fold TP: manual eligibility needs it
    for use_dp in dp_vals:
        wl = dp_view(w) if use_dp else w
        if use_dp and w.shape.global_batch % wl.mesh.zero_degree != 0:
            continue  # batch cannot shard over every chip
        seqs = wl.seqs_per_device
        ubs = [m for m in microbatches if seqs / m >= 1 and (seqs / m) % 1 == 0] or [1]
        best, evaluated = _search_inner(
            wl, capacity, ubs, sp_vals, gc_vals, use_dp, real_tp, allow_host,
            allow_swap, max_checkpoint_points, best, evaluated, overlap,
        )
    if best is not None:
        # refine the winning cell's activation axis: the scalar search only
        # saw the uniform n_checkpoint prefixes; the greedy vector sweep can
        # buy back remat latency with compressed saves where capacity allows.
        # Adopted only on a strict improvement, so uniform winners keep their
        # scalar (vector-free) plan representation.
        wl = dp_view(w) if best.plan.dp_only else w
        ref = search_act_policies(wl, best.plan, capacity)
        evaluated += ref.evaluated
        if ref.feasible and ref.runtime.t_iteration < best.runtime.t_iteration:
            best = ref
    if best is None:
        # nothing fits: report the minimal-footprint plan as infeasible
        plan = MemoryPlan(
            nc, nb, n_host=nc if allow_host else 0,
            n_checkpoint=nb, n_swap=0, microbatch=1,
        )
        best = SearchResult(
            plan, estimate_runtime(w, plan), estimate_memory(w, plan), evaluated, 0.0, False
        )
    best.search_seconds = time.time() - t0
    best.evaluated = evaluated
    return best


def _search_inner(w, capacity, ubs, sp_vals, gc_vals, use_dp, real_tp, allow_host,
                  allow_swap, max_checkpoint_points, best, evaluated,
                  overlap=True):
    nc, nb = w.n_chunks, w.n_blocks
    for ub, use_sp, (gc, sync) in itertools.product(ubs, sp_vals, gc_vals):
        manual = sync == "manual"
        if manual and real_tp > 1 and not use_dp:
            continue  # no manual kind lowers with a live TP axis (plan.py)
        # n_swap feasible set (paper: bounded by N_interval & bandwidth);
        # manual sync excludes swap (manual_sync_kind)
        swap_vals = [0]
        if allow_swap and not manual:
            for ns in _grid(nb, 5):
                if ns == 0:
                    continue
                probe = MemoryPlan(nc, nb, n_swap=ns, microbatch=ub,
                                   seq_shard_acts=use_sp, dp_only=use_dp,
                                   grad_compress=gc, sync_mode=sync)
                if estimate_runtime(w, probe).swap_feasible:
                    swap_vals.append(ns)
        for n_swap in swap_vals:
            for n_ckpt in _grid(nb - n_swap, max_checkpoint_points):
              for cg in ((1,) if n_ckpt == 0 else (1, 2, 4)):
               for hp in (True, False):  # full host offload vs ZeRO-Offload split

                def mk(n_persist=0, n_buffer=0, n_host=0, zero_stage=3):
                    return MemoryPlan(
                        nc, nb,
                        n_persist=n_persist, n_buffer=n_buffer, n_host=n_host,
                        n_swap=n_swap, n_checkpoint=n_ckpt, microbatch=ub,
                        seq_shard_acts=use_sp, dp_only=use_dp, ckpt_group=cg,
                        host_params=hp, grad_compress=gc, sync_mode=sync,
                        zero_stage=zero_stage, overlap=overlap,
                    )

                if manual:
                    # manual sync lowers for no-swap/no-host layouts. ZeRO-
                    # sharded chunks sync via the compressed reduce-scatter in
                    # two dataflows: "zero3" (lazy per-chunk gather — true
                    # ZeRO-3 param memory, so n_persist AND n_buffer are
                    # searchable exactly like the xla cells) and "zero2"
                    # (up-front gather: cheapest wire, n_buffer moot because
                    # the body gathers everything). All-persist plans lower
                    # as "ddp" (host_params is moot with zero host chunks).
                    # `evaluated` counts per candidate: one per stage here,
                    # one per cell on the xla branch below.
                    if not hp:
                        continue
                    for stage in (3, 2):
                        evaluated += 1
                        n_persist = _max_feasible(
                            0, nc, lambda v, _s=stage: _fits(
                                w, mk(n_persist=v, zero_stage=_s), capacity))
                        if n_persist < 0:
                            continue
                        plan = mk(n_persist=n_persist, zero_stage=stage)
                        if plan.manual_sync_kind(real_tp) is None:
                            # dp_only with a live TP axis only lowers DDP-
                            # style: the all-persist plan is the one manual
                            # candidate
                            plan = mk(n_persist=nc, zero_stage=stage)
                            if (plan.manual_sync_kind(real_tp) is None
                                    or not _fits(w, plan, capacity)):
                                continue
                        if plan.n_persist == nc:
                            if stage == 2:
                                continue  # same "ddp" plan as the stage-3 pass
                        elif stage == 3:
                            # zero3 re-gathers unbuffered chunks in BWD, so
                            # buffering is a real runtime knob again —
                            # maximize it under capacity (memory monotone)
                            n_buffer = _max_feasible(
                                0, nc - plan.n_persist,
                                lambda v, _p=plan.n_persist: _fits(
                                    w, mk(n_persist=_p, n_buffer=v,
                                          zero_stage=3), capacity))
                            plan = mk(n_persist=plan.n_persist,
                                      n_buffer=max(n_buffer, 0), zero_stage=3)
                        rt = estimate_runtime(w, plan)
                        mem = estimate_memory(w, plan)
                        cand = SearchResult(plan, rt, mem, evaluated, 0.0, True)
                        if best is None or rt.t_iteration < best.runtime.t_iteration:
                            best = cand
                    continue

                evaluated += 1
                # smallest-footprint config in this cell
                if not _fits(w, mk(), capacity):
                    if not allow_host:
                        continue
                    n_host = _max_feasible(1, nc, lambda v: not _fits(w, mk(n_host=v), capacity))
                    n_host = min(n_host + 1, nc)
                    if not _fits(w, mk(n_host=n_host), capacity):
                        continue  # cell infeasible even fully host-offloaded
                else:
                    n_host = 0
                # maximize persistence, then buffering (monotone in memory)
                n_persist = _max_feasible(
                    0, nc - n_host, lambda v: _fits(w, mk(n_persist=v, n_host=n_host), capacity)
                )
                n_persist = max(n_persist, 0)
                n_buffer = _max_feasible(
                    0,
                    nc - n_persist - n_host,
                    lambda v: _fits(w, mk(n_persist=n_persist, n_buffer=v, n_host=n_host), capacity),
                )
                n_buffer = max(n_buffer, 0)
                plan = mk(n_persist=n_persist, n_buffer=n_buffer, n_host=n_host)
                rt = estimate_runtime(w, plan)
                mem = estimate_memory(w, plan)
                if mem.peak >= capacity:
                    continue
                cand = SearchResult(plan, rt, mem, evaluated, 0.0, True)
                if best is None or rt.t_iteration < best.runtime.t_iteration:
                    best = cand
    return best, evaluated


def exhaustive_search(w: Workload, capacity_bytes: float, max_n: int = 6) -> SearchResult:
    """Brute force over the full 4-tuple (tests: validates the pruned search)."""
    t0 = time.time()
    nc, nb = w.n_chunks, w.n_blocks
    assert nc <= max_n + 2 and nb <= max_n + 2, "exhaustive search is for tiny models"
    best = None
    evaluated = 0
    for np_, nh in itertools.product(range(nc + 1), range(nc + 1)):
        if np_ + nh > nc:
            continue
        for nbuf in range(nc - np_ - nh + 1):
            for ns, nk in itertools.product(range(nb + 1), range(nb + 1)):
                if ns + nk > nb:
                    continue
                plan = MemoryPlan(nc, nb, n_persist=np_, n_buffer=nbuf, n_host=nh,
                                  n_swap=ns, n_checkpoint=nk)
                evaluated += 1
                mem = estimate_memory(w, plan)
                if mem.peak >= capacity_bytes:
                    continue
                rt = estimate_runtime(w, plan)
                if not rt.swap_feasible:
                    continue
                if best is None or rt.t_iteration < best.runtime.t_iteration:
                    best = SearchResult(plan, rt, mem, evaluated, 0.0, True)
    if best is None:
        plan = MemoryPlan(nc, nb, n_host=nc, n_checkpoint=nb)
        best = SearchResult(plan, estimate_runtime(w, plan), estimate_memory(w, plan),
                            evaluated, 0.0, False)
    best.search_seconds = time.time() - t0
    best.evaluated = evaluated
    return best
