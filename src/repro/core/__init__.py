"""ProTrain core: structured memory strategies, profiler, cost models, tuner."""
from repro.core.autotuner import SearchResult, exhaustive_search, search
from repro.core.chunks import ChunkInfo, chunk_inventory, chunk_size_search
from repro.core.cost_model import (
    Workload,
    build_workload,
    estimate_memory,
    estimate_runtime,
)
from repro.core.hardware import HARDWARE, MULTI_POD, SINGLE_POD, TPU_V5E, HardwareSpec, MeshSpec
from repro.core.plan import MemoryPlan, fsdp_style_plan, fully_resident_plan
from repro.core.profiler import BlockProfile, profile_fn, profile_superblock
