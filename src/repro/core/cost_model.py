"""Runtime + peak-memory cost models (paper Appendix A), TPU-adapted.

Both models are functions of a ``MemoryPlan`` over a ``Workload`` — one
profiling pass (abstract jaxpr, §profiler) feeds every candidate evaluation,
exactly the paper's "build cost models from a single profiling pass and
analytically evaluate all configurations".

Runtime (Eq. 2-7): per-chunk max(compute, communication) pipelines for FWD and
BWD, CPU(host)-update overlap, and host-link bandwidth contention between
activation swapping and parameter uploads (§3.3's "compound effects").

Memory (Eq. 8-11): block-granular replay of the FWD/BWD trajectory (the
paper's operator-wise iteration, at the granularity our planner acts on),
producing M_peak per device plus the trajectory for inspection (Fig. 2).

Gradient-sync wire costs are *calibrated*, not assumed: the per-(sync_mode,
grad_compress) wire factors default to the analytic table below, but a
calibration JSON produced by ``benchmarks/calibrate_wire.py`` — which fits
the factors against collective bytes measured from compiled dry-run HLO per
backend — overrides them (``load_wire_calibration`` / auto-load from the
packaged ``wire_calibration.json`` or ``$REPRO_WIRE_CALIBRATION``). The key
calibrated fact: under ``sync_mode="xla"`` compression is numerics-only (XLA
reduces the raw grads first; factor ~1.0), while ``sync_mode="manual"`` puts
the int8 payload on the wire but pays a gather-based all-reduce. Every term
and unit is documented in docs/cost_model.md; keep them in sync.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.chunks import ChunkInfo, chunk_inventory
from repro.core.hardware import HardwareSpec, MeshSpec
from repro.core.plan import MemoryPlan
from repro.core.profiler import BlockProfile, profile_superblock

ADAM_FLOPS_PER_PARAM = 12.0  # fused Adam: ~12 flops/param (exp avgs + update)
FP32 = 4

# Uncalibrated default wire-bytes multiplier for the gradient reduce under
# each compression mode, for the legacy in-jit ("xla") sync path. Kept for
# backward compatibility and as the fallback when no calibration JSON has
# been loaded — but note it encodes the *optimistic fiction* that in-jit
# compression halves wire bytes; measurement says it does not (the reduce XLA
# inserts moves the raw grads). Prefer wire_factor(), which consults the
# calibration produced by benchmarks/calibrate_wire.py.
GRAD_WIRE_FACTOR = {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5}

# Analytic defaults per (sync_mode, grad_compress), used until a calibration
# JSON overrides them. The xla column is 1.0 across the board — GSPMD reduces
# the raw gradients before the compression numerics run, a structural fact
# independent of backend — so a missing calibration file never re-introduces
# the 0.5 fiction into the search. "manual" factors are payload-size ratios
# vs the bf16 grads the uncompressed reduce moves; the topology cost of each
# manual pipeline is modeled separately in t_reduce. "int8_ef_rs" is the
# reduce-scatter pipeline for ZeRO-sharded chunks (manual_sync_kind zero2/
# zero3): same int8 payload ratio, but an all_to_all that moves (z-1)/z of
# the compressed bytes instead of the gather's (z-1) — calibrated from the
# s8 collective bytes in the compiled HLO (benchmarks/calibrate_wire.py).
# "gather_bf16" scales the *param* all-gathers of the manual ZeRO pipelines
# (lazy per-chunk gathers + BWD re-gathers, priced by t_gather) — fitted
# from the bf16 all-gather bytes of a zero3 program vs the modeled
# (z-1)/z-per-chunk topology bytes.
DEFAULT_WIRE_FACTORS = {
    # "act_compress" scales the quantize/dequantize HBM streams of the
    # compressed activation policies (compress8/compress16, priced by
    # Workload.t_act_compress_pass) against the analytic read-full +
    # write-compressed byte count — calibrated from the pallas_call block
    # census of the fused quantize kernel at activation shapes
    # (benchmarks/calibrate_wire.py's act_compress config). Present under
    # both sync modes: the policy seam is sync-agnostic.
    "xla": {"none": 1.0, "bf16": 1.0, "int8_ef": 1.0, "act_compress": 1.0},
    # "fused_quant" scales the *HBM pass* count of the fused int8
    # quantize+pack kernel (kernels/fused_quant.py) against the analytic
    # one-pass model — calibrated from the pallas_call block-spec bytes of
    # the jitted kernel (benchmarks/calibrate_wire.py's kernel configs).
    "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5, "int8_ef_rs": 0.5,
               "gather_bf16": 1.0, "fused_quant": 1.0, "act_compress": 1.0},
    # Serving pipelines (repro.serve). "h2d_page" scales the cold-page
    # fetch bytes of the paged decode step against the modeled
    # pages x page_bytes x attention-layers product — calibrated from the
    # page-fetch slices of the compiled paged program
    # (benchmarks/calibrate_wire.py's h2d_page config). "paged_attn" scales
    # the fused decode-attention kernel's per-layer cache stream (hot ring +
    # cold tiles, KERNEL_CACHE_PASSES analytic passes) the same way. Per-key
    # defaulting (schema v2) keeps pre-serving calibration files loading
    # cleanly.
    "serve": {"h2d_page": 1.0, "paged_attn": 1.0},
}

# fp32 error-feedback residual per param = 2x the bf16 grad bytes; the
# calibration JSON can override with the measured state-size delta.
DEFAULT_EF_RESIDUAL_FACTOR = 2.0

# Fraction of a block's forward a compressed-activation block replays in BWD.
# Full remat replays everything between scan boundaries (1.0); the compress
# policies save each layer's quantized site outputs (norm1/mixer/mlp — see
# models/model.apply_position), so the replay only recomputes the segments
# *between* saved sites: roughly half the forward's matmul work (the mixer
# and mlp matmuls re-run from dequantized inputs; their saved outputs are
# not re-derived from scratch). This is what makes compress strictly cheaper
# than uniform remat in the searched lattice — it buys memory with bytes
# (quantize/dequant streams) instead of FLOPs.
ACT_COMPRESS_RECOMPUTE = 0.5

# Calibration JSON schema version this build writes/understands. The loader
# is forward-compatible by construction: any factor key absent from a loaded
# file (older schema, partial backend entry) falls back to the analytic
# default above — wire_factor()/ef_residual_factor() never KeyError on old
# calibrations, they just price the missing pipeline analytically.
CALIBRATION_SCHEMA_VERSION = 2

_CALIBRATION: dict | None = None
_CALIBRATION_LOADED = False


def load_wire_calibration(path: str | None = None) -> dict | None:
    """Load (and activate) a wire-cost calibration JSON.

    Schema (written by benchmarks/calibrate_wire.py; versioned since v2):
      {"version": 2, "backends": {"<backend>": {"wire_factors": {"xla":
      {...}, "manual": {...}}, "ef_residual_factor": float, ...}}}
    Files without a "version" key are treated as v1 (pre-gather-factor) and
    load fine — every factor key a loaded entry lacks falls back to the
    analytic DEFAULT_WIRE_FACTORS/DEFAULT_EF_RESIDUAL_FACTOR value at lookup
    time, so an old-format JSON never KeyErrors the search.
    With ``path=None`` resolves ``$REPRO_WIRE_CALIBRATION``, then the packaged
    ``src/repro/core/wire_calibration.json``. Returns the active per-backend
    entry (matched against ``jax.default_backend()``, falling back to the
    first entry) or None when no file exists.
    """
    global _CALIBRATION, _CALIBRATION_LOADED
    _CALIBRATION_LOADED = True
    if path is None:
        path = os.environ.get("REPRO_WIRE_CALIBRATION") or os.path.join(
            os.path.dirname(__file__), "wire_calibration.json")
    if not os.path.exists(path):
        _CALIBRATION = None
        return None
    with open(path) as f:
        data = json.load(f)
    backends = data.get("backends", {})
    try:
        backend = jax.default_backend()
    except Exception:  # pragma: no cover - backend init can fail headless
        backend = None
    entry = backends.get(backend) or (next(iter(backends.values())) if backends else None)
    _CALIBRATION = entry
    return entry


def reset_wire_calibration() -> None:
    """Drop any loaded calibration (tests); next wire_factor() reloads."""
    global _CALIBRATION, _CALIBRATION_LOADED
    _CALIBRATION = None
    _CALIBRATION_LOADED = False


def _calibration() -> dict | None:
    if not _CALIBRATION_LOADED:
        load_wire_calibration()
    return _CALIBRATION


def wire_factor(sync_mode: str, compress: str) -> float:
    """Wire-bytes multiplier for the gradient reduce: calibrated when a
    calibration JSON is present, analytic default otherwise. ``compress``
    accepts the pipeline-qualified key ``"int8_ef_rs"`` (manual
    reduce-scatter for ZeRO-sharded chunks) in addition to the plain
    grad_compress values; calibrations predating the key fall back to the
    analytic default for it."""
    cal = _calibration()
    if cal is not None:
        try:
            return float(cal["wire_factors"][sync_mode][compress])
        except KeyError:
            pass
    return DEFAULT_WIRE_FACTORS[sync_mode][compress]


def ef_residual_factor() -> float:
    """EF residual bytes per grad byte (fp32 residual / bf16 grad = 2.0),
    calibrated against the measured train-state size delta when available."""
    cal = _calibration()
    if cal is not None and "ef_residual_factor" in cal:
        return float(cal["ef_residual_factor"])
    return DEFAULT_EF_RESIDUAL_FACTOR


@dataclasses.dataclass(frozen=True)
class Workload:
    """Everything the cost models need, profiled once per (cfg, shape, mesh)."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: MeshSpec
    hw: HardwareSpec
    chunks: list[ChunkInfo]
    block: BlockProfile  # one superblock, batch=1, full (unsharded) dims
    positions: int = 1  # layers per superblock (remat granularity)
    max_position_param_bytes: int = 0  # largest single layer's params (gather unit)

    @property
    def n_blocks(self) -> int:
        return sum(1 for c in self.chunks if c.is_block)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def seqs_per_device(self) -> float:
        return self.shape.global_batch / self.mesh.zero_degree

    def seqs_per_ubatch(self, plan: MemoryPlan) -> float:
        return self.seqs_per_device / plan.microbatch

    # ---- per-chunk compute (per microbatch, per device) -------------------
    def t_tp_allreduce(self, plan: MemoryPlan, n_ars: int = 2) -> float:
        """Megatron-style TP activation all-reduces on the critical path:
        ~2 per layer forward (attention out + MLP out), each moving the
        (B_ubatch, S, D) activation over the model axis."""
        t = self.mesh.tp_degree
        if t <= 1:
            return 0.0
        act = self.block.boundary_bytes * self.seqs_per_ubatch(plan)
        wire = 2.0 * (t - 1) / t * act
        bw = self.hw.ici_bw * self.hw.coll_efficiency
        return n_ars * self.positions * wire / bw

    def t_comp_fwd(self, chunk: ChunkInfo, plan: MemoryPlan) -> float:
        if not chunk.is_block:
            return self._t_embed_head(chunk, plan)
        scale = self.seqs_per_ubatch(plan) / self.mesh.tp_degree
        t_flops = self.hw.matmul_time(self.block.flops_fwd * scale)
        t_mem = self.hw.hbm_time(self.block.hbm_bytes_fwd * scale)
        return max(t_flops, t_mem) + self.t_tp_allreduce(plan)

    def t_comp_bwd(self, chunk: ChunkInfo, plan: MemoryPlan) -> float:
        return 2.0 * self.t_comp_fwd(chunk, plan)

    def _t_embed_head(self, chunk: ChunkInfo, plan: MemoryPlan) -> float:
        # head matmul: 2*B*S*D*V (embed lookup is bandwidth-only)
        cfg = self.cfg
        tokens = self.seqs_per_ubatch(plan) * self.shape.seq_len
        flops = 2.0 * tokens * cfg.d_model * cfg.vocab_size / self.mesh.tp_degree
        if chunk.name == "embed":
            return self.hw.hbm_time(chunk.param_bytes / self.mesh.tp_degree)
        return max(self.hw.matmul_time(flops), self.hw.hbm_time(chunk.param_bytes))

    # ---- per-chunk communication ------------------------------------------
    def t_gather(self, chunk: ChunkInfo, plan: MemoryPlan | None = None) -> float:
        """All-gather of a ZeRO-sharded chunk's params (Eq. 4 gather term).

        Under ``sync_mode="manual"`` the gathers are explicit bf16
        collectives (the zero3 lazy per-chunk gathers and the zero2 up-front
        gather), scaled by the calibrated ``gather_bf16`` factor — the
        measured bf16 all-gather bytes of a compiled zero3 program over this
        topology term (benchmarks/calibrate_wire.py)."""
        z = self.mesh.zero_degree
        nbytes = chunk.param_bytes / self.mesh.tp_degree
        if plan is not None and plan.sync_mode == "manual":
            nbytes *= wire_factor("manual", "gather_bf16")
        return nbytes * (z - 1) / z / self.mesh.gather_bw(self.hw)

    def t_upload(self, chunk: ChunkInfo, host_bw_eff: float) -> float:
        """Host->device shard upload for host-resident chunks (Eq. 4 upload)."""
        shard = chunk.param_bytes / (self.mesh.tp_degree * self.mesh.zero_degree)
        return shard / host_bw_eff

    def t_reduce(self, chunk: ChunkInfo, plan: MemoryPlan) -> float:
        """Gradient reduce (Eq. 6): all-reduce for persistent (replicated)
        chunks, reduce-scatter for sharded ones. The wire-bytes multiplier is
        the *calibrated* factor for (sync_mode, grad_compress) — see
        wire_factor() and docs/cost_model.md.

        sync_mode="manual" + int8_ef has two topologies, per chunk placement
        (dist/collectives.py):

          * persistent (replicated) chunk — gather-based all-reduce of the
            compressed payload (manual_int8_ef_sync): each chip receives
            (z-1) full payloads, vs the ring all-reduce's 2(z-1)/z passes —
            cheaper only while the compression ratio beats z/2;
          * ZeRO-sharded chunk — compressed reduce-scatter
            (manual_int8_ef_reduce_scatter): an all_to_all moving (z-1)/z of
            the int8 bytes, i.e. the scatter topology at the compressed
            payload size ("int8_ef_rs" factor) — roughly half the xla
            reduce-scatter's bf16 bytes, and 1/z of the gather pipeline's.

        Manual bf16/none use psum/psum_scatter (ring) like the xla path.
        """
        z = self.mesh.zero_degree
        bw = self.mesh.gather_bw(self.hw)
        sharded = (plan.chunk_placement(chunk.index) != "persist"
                   or plan.zero1_persistent)
        if plan.sync_mode == "manual" and plan.grad_compress == "int8_ef":
            if sharded:
                factor = wire_factor("manual", "int8_ef_rs")
                nbytes = chunk.grad_bytes * factor / self.mesh.tp_degree
                return (nbytes * (z - 1) / z / bw
                        + self._t_quantize_pass(chunk, fused_aware=True))
            factor = wire_factor("manual", "int8_ef")
            nbytes = chunk.grad_bytes * factor / self.mesh.tp_degree
            return (nbytes * (z - 1) / bw
                    + self._t_quantize_pass(chunk, fused_aware=False))
        factor = wire_factor(plan.sync_mode, plan.grad_compress)
        nbytes = chunk.grad_bytes * factor / self.mesh.tp_degree
        if not sharded:
            return 2.0 * nbytes * (z - 1) / z / bw
        return nbytes * (z - 1) / z / bw

    def _t_quantize_pass(self, chunk: ChunkInfo, *, fused_aware: bool) -> float:
        """HBM time of the int8 quantize+pack stage feeding the compressed
        reduce. The fp32 chunk working set (2x the bf16 grad bytes) is
        crossed once by the fused Pallas kernel (kernels/fused_quant.py:
        absmax + quantize + EF residual in one pass) vs three times by the
        unfused absmax/round/residual sequence, scaled by the calibrated
        "fused_quant" factor. Only the reduce-scatter pipeline dispatches to
        the fused kernel (dist/collectives.manual_int8_ef_reduce_scatter);
        the persistent gather variant stays unfused (``fused_aware=False``).
        """
        if fused_aware:
            from repro.dist.collectives import fused_quant_enabled

            passes = 1.0 if fused_quant_enabled() else 3.0
        else:
            passes = 3.0
        passes *= wire_factor("manual", "fused_quant")
        work = chunk.grad_bytes * 2.0 / self.mesh.tp_degree
        return self.hw.hbm_time(passes * work)

    def t_grad_offload(self, chunk: ChunkInfo, host_bw_eff: float) -> float:
        shard = chunk.grad_bytes / (self.mesh.tp_degree * self.mesh.zero_degree)
        return shard / host_bw_eff

    # ---- activation swap traffic -------------------------------------------
    def boundary_dev_bytes(self, plan: MemoryPlan) -> float:
        """Per-device bytes of one block-boundary activation (the scan carry).

        With sequence-parallel activation sharding the boundary is split over
        the TP axis as well as batch."""
        scale = self.seqs_per_ubatch(plan)
        b = self.block.boundary_bytes * scale
        return b / self.mesh.tp_degree if plan.seq_shard_acts else b

    def swap_bytes_per_block(self, plan: MemoryPlan) -> float:
        """Bytes offloaded to host per swap block per microbatch, per device.

        Swap offloads the block-*interior* residuals; the boundary (scan
        carry) stays on device (see plan.py)."""
        scale = self.seqs_per_ubatch(plan)
        return self.block.act_residual_bytes * scale / self.mesh.tp_degree

    def saved_bytes_per_block(self, plan: MemoryPlan, policy: str) -> float:
        """Device-resident activation bytes a block leaves behind in FWD.

        Remat is applied per *position* (layer) by default, so a checkpointed
        superblock saves one boundary per position; grouped checkpointing
        (ckpt_group=g) saves 1/g of them."""
        boundary = self.positions * self.boundary_dev_bytes(plan)
        if policy == "checkpoint":
            return boundary / max(plan.ckpt_group, 1)
        if policy == "swap":
            return boundary
        if policy in ("compress8", "compress16"):
            # the scan carries stay full precision; the per-layer site
            # tensors persist as the quantized payload
            return boundary + self.compressed_act_bytes(plan, policy)
        scale = self.seqs_per_ubatch(plan)
        inner = self.block.act_residual_bytes * scale / self.mesh.tp_degree
        return boundary + inner

    # ---- compressed activation policy (compress8 / compress16) -----------
    def act_sites_per_position(self) -> float:
        """Save sites one layer tags through the quantize-on-save seam
        (models/model.apply_position): norm1 output, mixer output, mlp/moe
        output — plus the cross-attention site on encoder-decoder stacks.
        Each site is one (B, S, D) boundary-shaped tensor."""
        return 4.0 if self.cfg.kind == "encdec" else 3.0

    def act_site_bytes_per_block(self, plan: MemoryPlan) -> float:
        """Full-precision bytes of one block's save-site tensors."""
        return (self.positions * self.act_sites_per_position()
                * self.boundary_dev_bytes(plan))

    def compressed_act_bytes(self, plan: MemoryPlan, policy: str) -> float:
        """One block's quantized payload resident FWD->BWD: int8 + per-row
        scales for compress8 (~1 B/elem), bf16 downcast for compress16."""
        import numpy as _np

        itemsize = _np.dtype(self.cfg.dtype).itemsize
        ratio = (1.0 if policy == "compress8" else 2.0) / itemsize
        return self.act_site_bytes_per_block(plan) * ratio

    def t_act_compress_pass(self, plan: MemoryPlan, policy: str) -> float:
        """HBM time of one quantize (FWD save) or dequantize (BWD use)
        stream over one block's sites: read full + write compressed (or the
        reverse), scaled by the calibrated act_compress factor."""
        nbytes = (self.act_site_bytes_per_block(plan)
                  + self.compressed_act_bytes(plan, policy))
        return self.hw.hbm_time(
            nbytes * wire_factor(plan.sync_mode, "act_compress"))

    def recompute_workspace(self, plan: MemoryPlan) -> float:
        """Peak residuals live while one rematted region is re-run in BWD:
        one position for per-layer remat, g superblocks for grouped remat."""
        scale = self.seqs_per_ubatch(plan)
        resid_sb = self.block.act_residual_bytes * scale / self.mesh.tp_degree
        if plan.ckpt_group > 1:
            return plan.ckpt_group * resid_sb + self.boundary_dev_bytes(plan)
        return resid_sb / self.positions + self.boundary_dev_bytes(plan)


def build_workload(
    cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec, hw: HardwareSpec
) -> Workload:
    import numpy as _np

    from repro.models.layers import ParamDef as _PD
    from repro.models.model import param_defs, superblock_period

    # largest single position's parameter bytes (the point-of-use gather unit)
    defs = param_defs(cfg)["blocks"]
    r = max(
        (d.shape[0] for d in jax.tree.leaves(
            defs, is_leaf=lambda x: isinstance(x, _PD))), default=1
    )
    max_pos = 0
    for pos, sub in defs.items():
        nbytes = sum(
            int(_np.prod(d.shape)) * (2 if d.dtype == "bfloat16" else 4)
            for d in jax.tree.leaves(sub, is_leaf=lambda x: isinstance(x, _PD))
        ) // r
        max_pos = max(max_pos, nbytes)

    return Workload(
        cfg=cfg,
        shape=shape,
        mesh=mesh,
        hw=hw,
        chunks=chunk_inventory(cfg),
        block=profile_superblock(cfg, 1, shape.seq_len),
        positions=superblock_period(cfg),
        max_position_param_bytes=max_pos,
    )


def step_totals(w: Workload, plan: MemoryPlan) -> tuple[float, float]:
    """(flops, hbm_bytes) per chip per training step — the trip-count-aware
    analytic oracle the roofline consumes (XLA CPU cost_analysis undercounts
    loop bodies)."""
    mesh = w.mesh
    scale = w.seqs_per_ubatch(plan)
    mb = plan.microbatch
    blocks = [c for c in w.chunks if c.is_block]
    f_fwd = w.block.flops_fwd * scale / mesh.tp_degree
    b_fwd = w.block.hbm_bytes_fwd * scale / mesh.tp_degree
    flops = bytes_ = 0.0
    for c in blocks:
        pol = plan.block_policy(c.block_index)
        recompute = 0.0
        if w.shape.is_training:
            if pol in ("checkpoint", "swap"):
                recompute = 1.0
            elif pol in ("compress8", "compress16"):
                recompute = ACT_COMPRESS_RECOMPUTE
        mult = (3.0 + recompute) if w.shape.is_training else 1.0
        flops += f_fwd * mult * mb
        bytes_ += b_fwd * mult * mb
        if pol in ("compress8", "compress16") and w.shape.is_training:
            # quantize-on-save (FWD) + dequantize-on-use (BWD) streams
            bytes_ += 2.0 * (w.act_site_bytes_per_block(plan)
                             + w.compressed_act_bytes(plan, pol)) * mb
    # head matmul + embed traffic
    tokens_dev = scale * w.shape.seq_len * mb
    head_flops = 2.0 * tokens_dev * w.cfg.d_model * w.cfg.vocab_size / mesh.tp_degree
    flops += head_flops * (3.0 if w.shape.is_training else 1.0)
    emb = w.chunks[0].param_bytes / mesh.tp_degree
    bytes_ += emb
    if w.shape.is_training:
        # optimizer traffic: read+write states (16 B/param resident view)
        for c in w.chunks:
            place = plan.chunk_placement(c.index)
            opt = (c.optim_bytes + c.param_bytes + c.grad_bytes) / mesh.tp_degree
            if place == "persist" and not plan.zero1_persistent:
                bytes_ += 2 * opt
            elif place != "host":
                bytes_ += 2 * opt / mesh.zero_degree
            flops += ADAM_FLOPS_PER_PARAM * c.param_count / mesh.n_chips
    return flops, bytes_


# ---------------------------------------------------------------------------
# Serving: paged KV-cache fetch terms (repro.serve; docs/serving.md)
# ---------------------------------------------------------------------------
def _attn_layer_count(cfg: ModelConfig) -> int:
    return sum(1 for layer in range(cfg.num_layers)
               if cfg.mixer_at(layer) == "attention")


def page_fetch_bytes_per_step(cfg: ModelConfig, shape: ShapeConfig,
                              mesh: MeshSpec, spec) -> float:
    """Per-device host-link bytes one paged decode step moves, worst case:
    every attention layer fetches its ``n_cold`` cold pages (k and v) while
    the hot window serves the rest from HBM. The write-through token update
    is negligible against the page reads and is not priced."""
    import numpy as np

    hd = cfg.resolved_head_dim
    itemsize = np.dtype(cfg.dtype).itemsize
    page_global = 2 * shape.global_batch * spec.page_size * cfg.num_kv_heads * hd * itemsize
    per_dev = page_global / (mesh.zero_degree * mesh.tp_degree)
    return spec.n_cold * per_dev * _attn_layer_count(cfg)


def t_page_fetch(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec,
                 hw: HardwareSpec, spec) -> float:
    """Host-link time of one paged decode step's cold-page fetches, at the
    calibrated ``h2d_page`` factor (wire_factor("serve", "h2d_page"))."""
    nbytes = page_fetch_bytes_per_step(cfg, shape, mesh, spec)
    return nbytes * wire_factor("serve", "h2d_page") / hw.host_bw


# HBM passes over each attention layer's cache working set in one paged
# decode step. The lax rebuild (serve/paging.PagedKV.update_and_fetch +
# _masked_decode_attn) reads the hot/cold sources, writes the gathered
# transient reconstruction, then re-reads it for attention: 3 passes. The
# fused Pallas kernel (kernels/paged_attention.py) streams hot-ring slices
# and cold-page tiles straight into the attention blocks — read K, read V,
# no transient materialization: 2 passes, scaled by the calibrated
# wire_factor("serve", "paged_attn").
LAX_REBUILD_CACHE_PASSES = 3.0
KERNEL_CACHE_PASSES = 2.0


def decode_kernel_active() -> bool:
    """Does the decode step route through the fused paged-attention kernel?

    Mirrors serve/paging.PagedKV's auto-resolution (kernel path engages
    when the kernels package dispatches to Pallas); host-sharded fetch
    plans keep the lax pipeline and price with ``kernel=False``."""
    try:
        from repro.kernels import pallas_kernels_active
    except Exception:  # pragma: no cover - kernels package import failure
        return False
    return pallas_kernels_active()


def paged_cache_read_bytes(cfg: ModelConfig, shape: ShapeConfig,
                           mesh: MeshSpec, spec,
                           kernel: bool | None = None) -> float:
    """Per-device HBM bytes one paged decode step reads from the KV cache:
    the resident hot rings plus each attention layer's per-step cache
    stream at the kernel-aware pass count (see LAX_REBUILD_CACHE_PASSES /
    KERNEL_CACHE_PASSES)."""
    from repro.core.serve_plan import _paged_parts_per_device

    if kernel is None:
        kernel = decode_kernel_active()
    parts = _paged_parts_per_device(cfg, shape, mesh, spec)
    if kernel:
        passes = KERNEL_CACHE_PASSES * wire_factor("serve", "paged_attn")
    else:
        passes = LAX_REBUILD_CACHE_PASSES
    return parts["hbm"] + passes * parts["transient"] * _attn_layer_count(cfg)


def t_decode_compute(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec,
                     hw: HardwareSpec, spec=None,
                     kernel: bool | None = None) -> float:
    """One decode step's compute window per device: the active-parameter
    matmuls against the weight + cache read bandwidth floor.

    With a paging ``spec`` the cache term is priced kernel-aware
    (``paged_cache_read_bytes``): the fused paged-attention kernel streams
    2 passes over each layer's cache working set where the lax rebuild
    takes 3, so the modeled decode window shrinks when the kernel is
    active. ``kernel=None`` auto-resolves via ``decode_kernel_active()``;
    without a spec the resident-cache pricing is unchanged."""
    b_loc = shape.global_batch / mesh.zero_degree
    flops = 2.0 * cfg.active_param_count() * b_loc / mesh.tp_degree
    weights_dev = sum(c.param_bytes for c in chunk_inventory(cfg)) / mesh.tp_degree
    from repro.core.serve_plan import cache_bytes_per_device

    if spec is None:
        read = weights_dev + cache_bytes_per_device(cfg, shape, mesh)
    else:
        read = weights_dev + paged_cache_read_bytes(cfg, shape, mesh, spec,
                                                    kernel=kernel)
    return max(hw.matmul_time(flops), hw.hbm_time(read))


# A prefill chunk interleaved into the decode loop stalls in-flight streams
# for its whole runtime: budget it at this many decode-step windows so the
# added inter-token latency stays bounded (the scheduler enforces at most
# one consecutive prefill tick on top — serve/scheduler.py:should_prefill).
PREFILL_STALL_BUDGET_STEPS = 8


def t_prefill_chunk(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec,
                    hw: HardwareSpec, chunk: int, spec=None) -> float:
    """Runtime of one chunked-prefill call ingesting ``chunk`` tokens/slot.

    The chunk program is a scan of ``chunk`` single-token decode steps
    (serve/prefill.py), so its cost is the decode-step window — compute vs.
    cold-page fetch, whichever dominates on a paged plan — times the chunk
    length. Priced next to ``t_page_fetch`` so the planner reasons about
    admission latency and fetch drain with one vocabulary."""
    per_tok = t_decode_compute(cfg, shape, mesh, hw, spec=spec)
    if spec is not None:
        per_tok = max(per_tok, t_page_fetch(cfg, shape, mesh, hw, spec))
    return chunk * per_tok


def choose_prefill_chunk(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec,
                         hw: HardwareSpec, spec=None,
                         max_chunk: int | None = None) -> int:
    """Largest prefill chunk whose runtime fits the decode-latency budget
    (``PREFILL_STALL_BUDGET_STEPS`` decode windows), clamped to
    [1, max_chunk]. Bigger chunks amortize per-call dispatch but each call
    stalls in-flight decode streams for ``t_prefill_chunk``; the budget caps
    that stall at a bounded number of inter-token latencies."""
    per_tok = t_decode_compute(cfg, shape, mesh, hw, spec=spec)
    if spec is not None:
        per_tok = max(per_tok, t_page_fetch(cfg, shape, mesh, hw, spec))
    budget = PREFILL_STALL_BUDGET_STEPS * t_decode_compute(cfg, shape, mesh, hw,
                                                           spec=spec)
    chunk = max(1, int(budget / per_tok)) if per_tok > 0 else (max_chunk or 1)
    if max_chunk is not None:
        chunk = min(chunk, max_chunk)
    return chunk


def page_fetch_feasible(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec,
                        hw: HardwareSpec, spec) -> bool:
    """Can the double-buffered prefetch hide the cold-page fetches?

    Mirrors the training path's ``swap_feasible`` drain check: the paged
    decode step overlaps h2d fetches with attention compute, so the pipeline
    sustains decode speed iff one step's fetch bytes drain within one step's
    compute window. Infeasible specs still *run* — they just decode at
    host-link speed — so the planner prefers feasible hot windows but may
    fall back (serve_plan)."""
    return t_page_fetch(cfg, shape, mesh, hw, spec) <= t_decode_compute(
        cfg, shape, mesh, hw, spec=spec)


def serve_totals(w: Workload, plan: MemoryPlan) -> tuple[float, float]:
    """(flops, hbm_bytes) per chip for one serve step (prefill or decode)."""
    mesh = w.mesh
    if w.shape.mode == "prefill":
        return step_totals(w, plan)
    # decode: one token, full weight + cache read
    b_loc = w.shape.global_batch / mesh.zero_degree
    n_active = w.cfg.active_param_count()
    flops = 2.0 * n_active * b_loc / mesh.tp_degree
    weights_dev = sum(c.param_bytes for c in w.chunks) / mesh.tp_degree
    if plan.n_persist < plan.n_chunks:
        weights_dev = weights_dev  # gathered through HBM once either way
    from repro.core.serve_plan import cache_bytes_per_device, paging_from_plan

    spec = paging_from_plan(w.cfg, w.shape, plan)
    if spec is None:
        cache_dev = cache_bytes_per_device(w.cfg, w.shape, mesh)
    else:
        # paged decode: HBM sees the hot rings plus each layer's per-step
        # cache stream at the kernel-aware pass count (the cold pages ride
        # the host link, priced separately by t_page_fetch)
        cache_dev = paged_cache_read_bytes(w.cfg, w.shape, mesh, spec)
    return flops, weights_dev + cache_dev


# ---------------------------------------------------------------------------
# Runtime model (Eq. 2-7)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RuntimeBreakdown:
    t_fwd: float
    t_bwd: float
    t_gpu_optim: float
    t_cpu_optim: float
    t_iteration: float
    tokens_per_second: float
    swap_feasible: bool

    def row(self) -> dict:
        return {k: round(v, 4) if isinstance(v, float) else v for k, v in vars(self).items()}


def _host_bw_contention(w: Workload, plan: MemoryPlan) -> tuple[float, bool]:
    """Effective host-link bandwidth left for parameter traffic when
    activation swapping shares the link (paper §3.3's contention modeling).

    Returns (effective host bw, swap feasible within compute window)."""
    hw = w.hw
    if plan.n_swap == 0:
        return hw.host_bw, True
    blocks = [c for c in w.chunks if c.is_block]
    t_fwd_compute = sum(w.t_comp_fwd(c, plan) for c in blocks)
    swap_total = plan.n_swap * w.swap_bytes_per_block(plan)
    swap_time = swap_total / hw.host_bw
    # swap must drain within the forward compute window (else it backs up
    # into the backward pass and stalls it — infeasible by construction)
    feasible = swap_time <= t_fwd_compute
    util = min(swap_time / max(t_fwd_compute, 1e-9), 1.0)
    return hw.host_bw * max(1.0 - util, 0.05), feasible


def estimate_runtime(w: Workload, plan: MemoryPlan) -> RuntimeBreakdown:
    host_bw_eff, feasible = _host_bw_contention(w, plan)
    n = w.n_chunks
    chunks = w.chunks
    manual_kind = (plan.manual_sync_kind(w.mesh.tp_degree)
                   if plan.sync_mode == "manual" else None)

    # --- comm/compute combine: overlap term (docs/cost_model.md §2) --------
    # The xla path always prices per-chunk comm as max(compute, comm) —
    # GSPMD's scheduler owns overlap there. Manual plans carry an explicit
    # knob: with ``plan.overlap`` (default) the deferred-accumulation
    # reduce-scatters, the prefetch-pipelined zero3 gathers, and the
    # barrier-ordered host fetches hide under compute, so each chunk prices
    # t_overlap = max(t_compute_chunk, t_comm_chunk); with ``overlap=False``
    # every manual comm term serializes (t_compute + t_comm) — that sum is
    # the pre-overlap schedule BENCH_train.json and the fidelity rows
    # compare against.
    serial_all = manual_kind is not None and not plan.overlap

    def combine(*terms: float) -> float:
        return sum(terms) if serial_all else max(terms)

    # --- forward (Eq. 3): pipeline of compute vs next-chunk prefetch -------
    t_fwd = 0.0
    for i in range(n + 1):
        t_comp = w.t_comp_fwd(chunks[i - 1], plan) if i >= 1 else 0.0
        if i >= 1 and chunks[i - 1].is_block:
            pol_f = plan.block_policy(chunks[i - 1].block_index)
            if pol_f in ("compress8", "compress16"):
                t_comp += w.t_act_compress_pass(plan, pol_f)  # quantize-on-save
        t_pref = 0.0
        if i < n:
            c = chunks[i]
            place = plan.chunk_placement(c.index)
            if place != "persist":
                t_pref = w.t_gather(c, plan)
                if place == "host" and plan.host_params:
                    t_pref += w.t_upload(c, host_bw_eff)
        t_fwd += combine(t_comp, t_pref)

    # --- backward (Eq. 5): compute+recompute vs re-gather vs reduce --------
    # BWD visits chunks in reverse execution order.
    order = list(range(n - 1, -1, -1))
    t_bwd = 0.0
    for idx, i in enumerate(order):
        c = chunks[i]
        t_comp = w.t_comp_bwd(c, plan)
        if c.is_block and plan.block_policy(c.block_index) == "checkpoint":
            t_comp += w.t_comp_fwd(c, plan)  # T_recomp
        if c.is_block and plan.block_policy(c.block_index) in ("compress8",
                                                              "compress16"):
            # partial replay of the segments between saved sites + the
            # dequantize-on-use stream
            pol_b = plan.block_policy(c.block_index)
            t_comp += (ACT_COMPRESS_RECOMPUTE * w.t_comp_fwd(c, plan)
                       + w.t_act_compress_pass(plan, pol_b))
        if c.is_block and plan.block_policy(c.block_index) == "swap":
            # activation fetch from host for this block (overlappable but
            # competes on the host link)
            t_fetch = w.swap_bytes_per_block(plan) / host_bw_eff
        else:
            t_fetch = 0.0
        # re-gather of the *next* chunk to be visited (Eq. 7): only when its
        # gathered weights were not buffered. Manual "zero2" gathers the whole
        # tree up front and keeps it live for the step, so it never re-gathers
        # regardless of n_buffer; "zero3" follows the xla path's buffering
        # semantics for block chunks (that is the point of the lazy-gather
        # refactor) while its non-block chunks (embed/head/encoder) are
        # gathered at point of use outside any remat region and survive to
        # BWD — no re-gather, like the xla path's fetch().
        t_pref = 0.0
        if idx + 1 < n:
            nxt = chunks[order[idx + 1]]
            buffered = (plan.chunk_buffered(nxt.index)
                        or manual_kind == "zero2"
                        or (manual_kind == "zero3" and not nxt.is_block))
            if plan.chunk_placement(nxt.index) != "persist" and not buffered:
                t_pref = w.t_gather(nxt, plan)
                if plan.chunk_placement(nxt.index) == "host" and plan.host_params:
                    t_pref += w.t_upload(nxt, host_bw_eff)
        # reduce+offload of the previous chunk's grads (Eq. 6)
        t_red = 0.0
        if idx >= 1:
            prv = chunks[order[idx - 1]]
            t_red = w.t_reduce(prv, plan)
            if plan.chunk_placement(prv.index) == "host" and plan.host_params:
                t_red += w.t_grad_offload(prv, host_bw_eff)
        t_bwd += combine(t_comp, t_pref, t_red, t_fetch)
    # tail: last visited chunk's reduce
    t_bwd += w.t_reduce(chunks[order[-1]], plan)

    # --- optimizer (Eq. 2) ---------------------------------------------------
    hw, mesh = w.hw, w.mesh
    t_gpu = t_cpu = 0.0
    for c in chunks:
        place = plan.chunk_placement(c.index)
        opt_traffic = (c.optim_bytes + c.param_bytes + c.grad_bytes) / mesh.tp_degree
        if place == "persist" and not plan.zero1_persistent:
            t_gpu += hw.hbm_time(2 * opt_traffic)  # read+write, replicated
        elif place == "host" and plan.host_optimizer:
            shard_params = c.param_count / (mesh.tp_degree * mesh.zero_degree)
            t_flops = ADAM_FLOPS_PER_PARAM * shard_params / hw.host_flops
            t_dma = 26.0 * shard_params / hw.host_bw  # m+v+master down + back (+p)
            t_cpu += max(t_flops, t_dma)
        else:
            t_gpu += hw.hbm_time(2 * opt_traffic / mesh.zero_degree)

    mb = plan.microbatch
    t_iter = mb * t_fwd + max(mb * t_bwd + t_gpu, t_cpu)
    tokens = w.shape.global_batch * w.shape.seq_len
    return RuntimeBreakdown(
        t_fwd=mb * t_fwd,
        t_bwd=mb * t_bwd,
        t_gpu_optim=t_gpu,
        t_cpu_optim=t_cpu,
        t_iteration=t_iter,
        tokens_per_second=tokens / t_iter,
        swap_feasible=feasible,
    )


# ---------------------------------------------------------------------------
# Memory model (Eq. 8-11): block-granular trajectory replay
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MemoryBreakdown:
    model_states: float
    gathered_buffers: float
    activations: float
    workspace: float
    logits: float
    peak: float
    trajectory: list[float]  # M_cur over fwd blocks then bwd blocks (Fig. 2)

    def row(self) -> dict:
        d = {k: round(v / 1e9, 3) for k, v in vars(self).items() if isinstance(v, float)}
        return d


def estimate_memory(w: Workload, plan: MemoryPlan, ce_chunk: int = 2048) -> MemoryBreakdown:
    mesh, cfg = w.mesh, w.cfg
    tp, z = mesh.tp_degree, mesh.zero_degree

    # --- resident model states (Eq. 11's M_persist / M_buffer terms) -------
    # int8_ef carries an fp32 error-feedback residual per param (calibrated
    # factor, default 2x the bf16 grad bytes), sharded/placed exactly like
    # the gradients it corrects.
    ef = ef_residual_factor() if plan.grad_compress == "int8_ef" else 0.0
    states = 0.0
    gathered = 0.0
    for c in w.chunks:
        place = plan.chunk_placement(c.index)
        full = (c.param_bytes + c.grad_bytes * (1 + ef) + c.optim_bytes) / tp
        if place == "persist":
            if plan.zero1_persistent:
                states += (c.param_bytes + c.grad_bytes * (1 + ef)) / tp + c.optim_bytes / (tp * z)
            else:
                states += full
        elif place == "hbm":
            states += full / z
        elif place == "host" and not plan.host_params:
            # ZeRO-Offload split (+ device-resident EF residual, if any)
            states += (c.param_bytes + c.grad_bytes * (1 + ef)) / (tp * z)
        elif place == "host":
            states += ef * c.grad_bytes / (tp * z)  # EF residual stays on device
        if plan.chunk_buffered(c.index) and place != "persist":
            gathered += c.param_bytes / tp
    # host chunks: grads live on device only in a 2-chunk reduce->offload window
    host_blocks = [c for c in w.chunks if plan.chunk_placement(c.index) == "host"]
    if host_blocks:
        states += 2 * max(c.grad_bytes for c in host_blocks) / (tp * z)
    manual_kind = (plan.manual_sync_kind(tp) if plan.sync_mode == "manual"
                   else None)
    if manual_kind == "zero2":
        # manual ZeRO-2 gathers every non-persistent chunk's bf16 params up
        # front and keeps them live for the whole step (full bf16 params,
        # shard-resident fp32 states/grads); buffered chunks were already
        # charged above. The "zero3" kind deliberately has NO such term —
        # its lazy per-chunk gathers live only inside the scan, so it pays
        # exactly the xla path's charges: buffered chunks (above) plus the
        # two in-flight gather units (below).
        gathered += sum(
            c.param_bytes for c in w.chunks
            if plan.chunk_placement(c.index) != "persist"
            and not plan.chunk_buffered(c.index)
        ) / tp
    elif manual_kind == "zero3":
        # zero3's non-block chunks (embed/head/encoder) are gathered at
        # point of use outside any remat region, so their gathered leaves
        # survive FWD->BWD regardless of n_buffer — charge them resident
        # (block chunks follow the xla-path buffering charges above)
        gathered += sum(
            c.param_bytes for c in w.chunks
            if not c.is_block
            and plan.chunk_placement(c.index) != "persist"
            and not plan.chunk_buffered(c.index)
        ) / tp
    # two in-flight gather buffers (prefetch + execute), the paper's n_buffer>=2
    # floor. The gather unit is one *position* (layer): hybrids/MoE gather a
    # 44B-param superblock layer-by-layer, not all at once.
    blocks = [c for c in w.chunks if c.is_block]
    if blocks and any(plan.chunk_placement(c.index) != "persist" for c in w.chunks):
        unit = w.max_position_param_bytes or max(c.param_bytes for c in blocks)
        gathered += 2 * unit / tp

    # --- activations (Eq. 8) -------------------------------------------------
    acts = 0.0
    traj = []
    for b in range(w.n_blocks):
        acts += w.saved_bytes_per_block(plan, plan.block_policy(b))
        traj.append(states + gathered + acts)

    # --- backward trajectory (Eq. 9-10 at block granularity) ---------------
    peak_bwd = 0.0
    cur = acts
    scale = w.seqs_per_ubatch(plan)
    recompute_ws = w.recompute_workspace(plan)
    grad_ws = w.boundary_dev_bytes(plan)  # dL/dx flowing between blocks
    transient = w.block.peak_transient_bytes * scale / tp / w.positions
    for b in range(w.n_blocks - 1, -1, -1):
        pol = plan.block_policy(b)
        # I_checkpoint term; the compress policies replay per-position
        # segments from the dequantized sites, so they carry the same
        # per-position replay workspace as checkpoint
        extra = (recompute_ws
                 if pol in ("checkpoint", "swap", "compress8", "compress16")
                 else 0.0)
        cur_peak = states + gathered + cur + extra + grad_ws + transient
        peak_bwd = max(peak_bwd, cur_peak)
        traj.append(cur_peak)
        cur -= w.saved_bytes_per_block(plan, pol)
        cur = max(cur, 0.0)

    # --- logits / loss workspace (chunked cross-entropy) --------------------
    toks = min(ce_chunk, w.shape.seq_len) * max(scale, 1.0)
    logits = toks * cfg.vocab_size / tp * (2 + FP32)  # bf16 logits + fp32 softmax
    if not w.shape.is_training:
        logits = max(scale, 1.0) * cfg.vocab_size / tp * (2 + FP32)

    workspace = w.block.peak_transient_bytes * scale / tp / w.positions
    if plan.sync_mode == "manual":
        # Per-kind sync workspace. Leaf size is approximated by the largest
        # single layer / non-block chunk (the embed table usually dominates).
        leaf = max([w.max_position_param_bytes]
                   + [c.param_bytes for c in w.chunks if not c.is_block])
        import numpy as _np

        elems = leaf / _np.dtype(cfg.dtype).itemsize
        a2a = elems * 5.0 if plan.grad_compress == "int8_ef" else 0.0
        if manual_kind == "zero2":
            # post-AD reduce-scatter workspace, any wire format: one
            # microbatch's *full* local grad tree exists before the sync
            # collapses it to shard size (the sharded chunks' persistent
            # grads are only charged /z above). int8 additionally holds the
            # all_to_all buffers of the largest leaf — int8 chunk payload
            # (~1 B/elem) + the owner's fp32 dequantized shards (z shards of
            # N/z elems at 4 B) ~ 5 B/elem.
            grads_full = sum(
                c.grad_bytes for c in w.chunks
                if plan.chunk_placement(c.index) != "persist") / tp
            workspace = max(workspace, grads_full + a2a)
        elif manual_kind == "zero3":
            # the lazy-gather VJP reduce-scatters each leaf's cotangent the
            # moment AD produces it, so no full-grad-tree workspace exists —
            # only the largest chunk's full cotangent is transiently live
            # (plus the all_to_all buffers of its largest leaf).
            chunk_grad = max(
                (c.grad_bytes for c in w.chunks
                 if plan.chunk_placement(c.index) != "persist"),
                default=0) / tp
            workspace = max(workspace, chunk_grad + a2a)
        elif plan.grad_compress == "int8_ef":
            # gather-based sync: the largest gradient leaf is all-gathered as
            # int8 (z x N x 1B) and dequantized to fp32 (z x N x 4B) before
            # the mean collapses it — both live at once at the end of each
            # microbatch's backward.
            workspace = max(workspace, z * elems * 5.0)
    peak = max(max(traj) if traj else 0.0, states + gathered + workspace) + logits
    return MemoryBreakdown(
        model_states=states,
        gathered_buffers=gathered,
        activations=acts,
        workspace=workspace,
        logits=logits,
        peak=peak,
        trajectory=traj,
    )


# ---------------------------------------------------------------------------
# Overlap schedule simulator (tests/test_overlap.py property suite)
# ---------------------------------------------------------------------------
def zero3_prefetch_schedule(n_chunks: int, n_buffer: int, microbatch: int = 1,
                            prefetch_depth: int | None = None) -> dict:
    """Pure event-level replay of the manual zero3 gather schedule.

    Mirrors the lowered program (models/model.apply_runs prefetch path +
    step_builder's run layout, with n_persist = 0): buffered chunks are the
    last ``n_buffer``; inside the buffered run the pipeline prefetches chunk
    k+1's gather during chunk k's compute when ``prefetch_depth >= 2``;
    unbuffered chunks gather at point of use and free on exit; BWD visits in
    reverse, re-gathering unbuffered chunks transiently and consuming
    buffered ones. Each microbatch repeats the whole FWD+BWD (buffers never
    carry across microbatches).

    Returns ``{"max_live": ..., "max_inflight": ...}`` — the peak count of
    simultaneously live gathered chunk buffers, and the peak count of
    gathers issued but not yet consumed by compute. ``estimate_memory``
    charges ``n_buffer`` full buffered chunks plus two in-flight gather
    units for the same plan, so the schedule invariant the property test
    holds is ``max_live <= max(n_buffer, 1)`` (never more than the buffered
    set, one transient unit when nothing is buffered) and
    ``max_inflight <= prefetch_depth - 1``.
    """
    assert 0 <= n_buffer <= n_chunks and microbatch >= 1
    if prefetch_depth is None:
        prefetch_depth = 2 if n_buffer >= 2 else 1

    def buffered(i: int) -> bool:
        return i >= n_chunks - n_buffer

    max_live = max_inflight = 0
    for _ in range(microbatch):
        live: set[int] = set()
        inflight: set[int] = set()
        # forward
        for i in range(n_chunks):
            if i not in live:
                live.add(i)  # gather at point of use
            inflight.discard(i)  # compute consumes the prefetched gather
            if (prefetch_depth >= 2 and buffered(i) and i + 1 < n_chunks
                    and buffered(i + 1)):
                live.add(i + 1)
                inflight.add(i + 1)
            max_live = max(max_live, len(live))
            max_inflight = max(max_inflight, len(inflight))
            if not buffered(i):
                live.discard(i)  # freed on scan-carry exit
        # backward (reverse order); buffered buffers are consumed by their
        # own chunk's backward, unbuffered ones re-gather transiently
        for i in range(n_chunks - 1, -1, -1):
            if i not in live:
                live.add(i)
            max_live = max(max_live, len(live))
            live.discard(i)
        assert not live and not inflight
    return {"max_live": max_live, "max_inflight": max_inflight}
