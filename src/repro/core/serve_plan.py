"""Serving (prefill/decode) memory planning.

Serving has no gradients or optimizer states, so chunk management degenerates
to persist-vs-gather for weights — plus, since the paged KV subsystem
(repro.serve), a second memory tier for the *cache*: ``MemoryPlan.n_host``
on a serve plan counts KV-cache pages offloaded to host memory (cold pages),
not host-resident weight chunks. The planner:

  1. keeps everything resident when weights + cache fit inside
     ``hw.serve_resident_headroom`` of the HBM budget
     (``hw.capacity_bytes()``, shared with the training search — Eq. 1's
     M_capacity);
  2. otherwise, while the weight stack alone still fits, pages the KV
     cache: searches the largest hot window (most HBM use, least host
     traffic) whose footprint fits the budget AND whose cold-page fetches
     drain inside the decode compute window — the ``page_fetch_feasible``
     term, mirroring the training path's ``swap_feasible`` host-link drain
     check (docs/serving.md §3). When no window satisfies both, the
     planner returns the *least-infeasible* layout rather than pretending:
     the largest window that at least fits, else the minimum-HBM one-page
     window (ZeRO-sharding the weights would not shrink the cache, so a
     paged-but-tight plan still beats that fallback; callers see the truth
     via ``serve_memory_estimate`` peak vs ``hw.capacity_bytes()``);
  3. only when the weights themselves overflow does it fall back to
     ZeRO-sharding the weight stack (gather per layer).

``paging_from_plan`` is the inverse mapping the step builder uses: a serve
plan's ``n_host`` (+ the module page-size default) back to a
``serve.paging.PagingSpec``.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.chunks import chunk_inventory
from repro.core.hardware import HardwareSpec, MeshSpec
from repro.core.plan import MemoryPlan
from repro.models import kvcache as KV
from repro.models.model import num_repeats

# Default page size (tokens). Large enough that a page's h2d transfer is
# bandwidth-bound rather than latency-bound on PCIe/host-DMA links, small
# enough that the hot-window search has resolution at decode_32k contexts.
PAGE_SIZE = 256


def cache_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec) -> float:
    specs = KV.cache_specs(cfg, shape.global_batch, shape.seq_len)
    total = sum(
        int(np.prod(s.shape)) * s.dtype.itemsize
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    )
    # batch over ZeRO axes; seq (attention) / heads (mamba) over TP
    return total / (mesh.zero_degree * mesh.tp_degree)


def _paged_parts_per_device(cfg, shape, mesh: MeshSpec, spec) -> dict[str, float]:
    """serve.paging.cache_partition_bytes scaled to per-device shards."""
    from repro.serve.paging import cache_partition_bytes

    parts = cache_partition_bytes(cfg, shape.global_batch, shape.seq_len, spec)
    scale = mesh.zero_degree * mesh.tp_degree
    return {k: v / scale for k, v in parts.items()}


def default_paging_spec(cfg: ModelConfig, shape: ShapeConfig, n_hot: int | None = None):
    """PagingSpec for this (cfg, shape) at the module page size; ``n_hot``
    None means fully hot (no cold pages)."""
    from repro.serve.paging import choose_paging

    s_kv = KV.cache_len(cfg, shape.seq_len)
    # resolve the real page geometry first (choose_paging may shrink the
    # page size to a divisor of s_kv, changing the page count), THEN clamp
    # the hot request against it — n_hot=None really is fully hot
    base = choose_paging(s_kv, PAGE_SIZE, 1)
    return choose_paging(s_kv, base.page_size,
                         base.n_pages if n_hot is None else n_hot)


def paging_from_plan(cfg: ModelConfig, shape: ShapeConfig, plan: MemoryPlan):
    """Recover the PagingSpec a serve plan's ``n_host`` (cold pages) encodes;
    None for resident plans. ``n_host`` only carries the page meaning on
    all-persistent plans — on sharded-weight plans it keeps its training
    semantics (host weight chunks).

    Divisibility caveat: the hot window must tile the page ring, so a
    hand-written ``n_host`` whose complement does not divide the page count
    is clamped (``choose_paging``) — the derived ``spec.n_cold`` can then
    exceed ``plan.n_host``. Every consumer (step builder, memory estimate,
    serve_totals) derives through this one function, so they stay mutually
    consistent; planner-emitted plans always round-trip exactly
    (``serve_plan`` only proposes divisor-valid windows)."""
    if plan.cold_kv_pages <= 0:
        return None
    full = default_paging_spec(cfg, shape)
    n_hot = max(1, full.n_pages - plan.cold_kv_pages)
    from repro.serve.paging import choose_paging

    return choose_paging(full.cache_len, full.page_size, n_hot)


def serve_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec, hw: HardwareSpec) -> MemoryPlan:
    from repro.core.cost_model import page_fetch_feasible

    chunks = chunk_inventory(cfg)
    nc, nb = len(chunks), num_repeats(cfg)
    weights_dev = sum(c.param_bytes for c in chunks) / mesh.tp_degree
    cache_dev = cache_bytes_per_device(cfg, shape, mesh)
    budget = hw.capacity_bytes()
    if weights_dev + cache_dev < hw.serve_resident_headroom * budget:
        return MemoryPlan(n_chunks=nc, n_blocks=nb, n_persist=nc)

    # page the cache: the cache is the overflowing tenant whenever the
    # weight stack alone still fits — prefer host pages over weight
    # sharding then. Candidate hot windows are scanned largest-first (most
    # HBM use -> least host traffic); the first fetch-feasible one wins,
    # else the largest that fits at all (a slow link beats an OOM), else
    # the minimum-HBM one-page window.
    if shape.mode == "decode" and not cfg.attention_free:
        full = default_paging_spec(cfg, shape)
        fitting: list = []
        for n_hot in range(full.n_pages - 1, 0, -1):
            if full.n_pages % n_hot:
                continue  # hot window must tile the page ring
            spec = default_paging_spec(cfg, shape, n_hot)
            parts = _paged_parts_per_device(cfg, shape, mesh, spec)
            dev_cache = parts["hbm"] + parts["transient"]
            if weights_dev + dev_cache < hw.serve_resident_headroom * budget:
                fitting.append(spec)
        chosen = None
        for spec in fitting:
            if page_fetch_feasible(cfg, shape, mesh, hw, spec):
                chosen = spec
                break
        if chosen is None and fitting:
            chosen = fitting[0]
        if chosen is None and full.n_pages > 1 and (
                weights_dev < hw.serve_resident_headroom * budget):
            chosen = default_paging_spec(cfg, shape, 1)
        if chosen is not None:
            return MemoryPlan(n_chunks=nc, n_blocks=nb, n_persist=nc,
                              n_host=chosen.n_cold)

    # weights are the overflowing tenant (or paging cannot apply): ZeRO-shard
    # the stack and gather per layer. Combining sharded weights with paged
    # caches in one plan is future work — n_host on a non-all-persistent plan
    # still means host-resident weight chunks (training semantics).
    return MemoryPlan(n_chunks=nc, n_blocks=nb, n_persist=0)


def serve_memory_estimate(cfg, shape, mesh: MeshSpec, plan: MemoryPlan) -> dict:
    """Per-device memory picture of a serve plan.

    Keys: ``weights_gb``, ``cache_gb`` (device-resident cache: the full
    cache for resident plans, hot rings + one layer's gathered transient for
    paged ones), ``host_cache_gb`` (cold pages), ``peak_gb`` (device).
    """
    chunks = chunk_inventory(cfg)
    weights = sum(c.param_bytes for c in chunks)
    if plan.n_persist == plan.n_chunks:
        w_dev = weights / mesh.tp_degree
    else:
        blk = max((c.param_bytes for c in chunks if c.is_block), default=0)
        w_dev = weights / (mesh.tp_degree * mesh.zero_degree) + 2 * blk / mesh.tp_degree
    spec = paging_from_plan(cfg, shape, plan)
    if spec is None:
        cache = cache_bytes_per_device(cfg, shape, mesh)
        host_cache = 0.0
    else:
        parts = _paged_parts_per_device(cfg, shape, mesh, spec)
        cache = parts["hbm"] + parts["transient"]
        host_cache = parts["host"]
    return {
        "weights_gb": w_dev / 1e9,
        "cache_gb": cache / 1e9,
        "host_cache_gb": host_cache / 1e9,
        "peak_gb": (w_dev + cache) / 1e9,
    }
