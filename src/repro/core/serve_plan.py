"""Serving (prefill/decode) memory planning.

Serving has no gradients or optimizer states, so chunk management degenerates
to persist-vs-gather for weights (paper's scope is training; we still plan the
decode cells). Heuristic: keep the whole weight stack persistent when it fits
comfortably next to the KV cache; otherwise ZeRO-shard the blocks and gather
per layer.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.chunks import chunk_inventory
from repro.core.hardware import HardwareSpec, MeshSpec
from repro.core.plan import MemoryPlan
from repro.models import kvcache as KV
from repro.models.model import num_repeats


def cache_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec) -> float:
    specs = KV.cache_specs(cfg, shape.global_batch, shape.seq_len)
    total = sum(
        int(np.prod(s.shape)) * s.dtype.itemsize
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    )
    # batch over ZeRO axes; seq (attention) / heads (mamba) over TP
    return total / (mesh.zero_degree * mesh.tp_degree)


def serve_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSpec, hw: HardwareSpec) -> MemoryPlan:
    chunks = chunk_inventory(cfg)
    nc, nb = len(chunks), num_repeats(cfg)
    weights_dev = sum(c.param_bytes for c in chunks) / mesh.tp_degree
    cache_dev = cache_bytes_per_device(cfg, shape, mesh)
    budget = hw.hbm_bytes * 0.9
    if weights_dev + cache_dev < 0.7 * budget:
        return MemoryPlan(n_chunks=nc, n_blocks=nb, n_persist=nc)
    # ZeRO-shard everything; decode gathers layer by layer
    return MemoryPlan(n_chunks=nc, n_blocks=nb, n_persist=0)


def serve_memory_estimate(cfg, shape, mesh: MeshSpec, plan: MemoryPlan) -> dict:
    chunks = chunk_inventory(cfg)
    weights = sum(c.param_bytes for c in chunks)
    if plan.n_persist == plan.n_chunks:
        w_dev = weights / mesh.tp_degree
    else:
        blk = max((c.param_bytes for c in chunks if c.is_block), default=0)
        w_dev = weights / (mesh.tp_degree * mesh.zero_degree) + 2 * blk / mesh.tp_degree
    cache = cache_bytes_per_device(cfg, shape, mesh)
    return {
        "weights_gb": w_dev / 1e9,
        "cache_gb": cache / 1e9,
        "peak_gb": (w_dev + cache) / 1e9,
    }
