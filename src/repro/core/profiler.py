"""Memory-aware profiler (§3.2), adapted to JAX.

The paper instruments PyTorch with allocator hooks because layer-wise hooks
miss (a) transient intra-operator allocations and (b) "unhookable" functional
ops. Under JAX we can do structurally better: tracing a step to a jaxpr gives
us *every* primitive — nothing is unhookable — and abstract interpretation of
the jaxpr (liveness replay) reconstructs the allocate-before-free memory
trajectory without running the model, which is the exact analogue of the
paper's on-demand profiling pass ("reduces peak memory to that of the largest
single operator"): here the cost is zero bytes, not one operator.

Outputs per op: FLOPs, HBM traffic, output ("current delta") bytes, transient
bytes, plus the running live-set M_cur — the Δ terms of Eq. 9-10. Per block:
activation residuals that AD would save (split into weight-derived vs
activation-derived, which is what the n_buffer semantics needs).

The same walker doubles as the trip-count-aware FLOPs/bytes oracle for the
roofline analysis (XLA's cost_analysis does not multiply while-loop bodies).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np
from jax.extend import core as jcore

# primitives whose transpose rule needs their *inputs* saved as residuals
_NONLINEAR = {
    "exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt", "sin", "cos",
    "integer_pow", "pow", "max", "min", "div", "rem", "cumsum",
    "custom_jvp_call",  # jax.nn.gelu/silu etc. lower through this
}
_MATMUL = {"dot_general"}
# ops that need extra workspace beyond their output (paper's intra-op spike)
_TRANSIENT = {"sort", "top_k", "gather", "scatter", "scatter-add", "concatenate"}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
    contract = int(np.prod([a.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([s for i, s in enumerate(a.shape) if i not in set(lc) | set(lb)]))
    n = int(np.prod([s for i, s in enumerate(b.shape) if i not in set(rc) | set(rb)]))
    return 2.0 * batch * m * n * contract


@dataclasses.dataclass
class OpRecord:
    name: str
    flops: float
    bytes_in: int
    bytes_out: int
    transient_bytes: int
    live_bytes: int  # M_cur after this op (liveness replay)


@dataclasses.dataclass
class TraceProfile:
    ops: list[OpRecord]
    peak_live_bytes: int  # on-demand liveness peak (no residual persistence)
    total_flops: float
    total_bytes: int  # HBM traffic proxy: sum of in+out per op
    residual_act_bytes: int  # AD residuals from activations
    residual_weight_bytes: int  # AD residuals that are raw weights
    largest_op_bytes: int

    def summary(self) -> dict:
        return {
            "ops": len(self.ops),
            "gflops": self.total_flops / 1e9,
            "traffic_gb": self.total_bytes / 1e9,
            "peak_live_mb": self.peak_live_bytes / 1e6,
            "resid_act_mb": self.residual_act_bytes / 1e6,
        }


def _walk(jaxpr, *, weight_vars: set, mult: float, ops: list, resid: dict, depth=0):
    """Recursive jaxpr walk. Returns (flops, traffic, peak_live, largest_op)."""
    # liveness: last use index per var
    last_use: dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if isinstance(v, jcore.Var):
                last_use[v] = i
    for v in jaxpr.outvars:
        if isinstance(v, jcore.Var):
            last_use[v] = len(jaxpr.eqns)

    live = {v: _aval_bytes(v.aval) for v in jaxpr.invars if isinstance(v, jcore.Var)}
    cur = sum(live.values())
    peak = cur
    flops = traffic = 0.0
    largest = 0

    for i, eqn in enumerate(jaxpr.eqns):
        prim = eqn.primitive.name
        in_b = sum(_aval_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)

        inner = None
        inner_mult = 1.0
        if prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            inner_mult = eqn.params["length"]
        elif prim == "while":
            inner = eqn.params["body_jaxpr"].jaxpr
            inner_mult = eqn.params.get("trip_count") or 1.0
        elif prim in ("pjit", "closed_call", "custom_vjp_call_jaxpr",
                      "custom_vjp_call", "remat"):
            # the body's param key varies across jax versions:
            # jaxpr (pjit/remat) | call_jaxpr (newer custom_vjp) | fun_jaxpr (older)
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("fun_jaxpr"))
            inner = getattr(sub, "jaxpr", sub)
        elif prim == "custom_jvp_call" and "call_jaxpr" in eqn.params:
            inner = eqn.params["call_jaxpr"].jaxpr
        elif prim == "cond":
            branches = eqn.params["branches"]
            inner = branches[0].jaxpr  # cost of one branch

        if inner is not None:
            f, t, p, lo = _walk(
                inner, weight_vars=set(), mult=mult * inner_mult, ops=ops,
                resid=resid, depth=depth + 1,
            )
            flops += f * inner_mult
            traffic += t * inner_mult
            peak = max(peak, cur + p)
            largest = max(largest, lo)
        else:
            f = _dot_flops(eqn) if prim in _MATMUL else float(out_b // max(
                eqn.outvars[0].aval.dtype.itemsize if eqn.outvars else 1, 1))
            if prim in ("broadcast_in_dim", "reshape", "transpose", "convert_element_type",
                        "squeeze", "slice", "iota", "copy"):
                f = 0.0
            flops += f
            traffic += in_b + out_b
            transient = out_b if prim in _TRANSIENT else 0
            # residual classification for AD
            if depth == 0 or True:
                if prim in _MATMUL:
                    for v in eqn.invars:
                        if isinstance(v, jcore.Var) and v not in resid:
                            kind = "w" if v in weight_vars else "a"
                            resid[v] = (kind, _aval_bytes(v.aval))
                elif prim in _NONLINEAR:
                    for v in eqn.invars:
                        if isinstance(v, jcore.Var) and v not in resid:
                            resid[v] = ("a", _aval_bytes(v.aval))
            cur += out_b
            peak = max(peak, cur + transient)
            largest = max(largest, in_b + out_b + transient)
            ops.append(OpRecord(prim, f * mult, in_b, out_b, transient, cur))

        # free vars whose last use has passed
        for v in list(live):
            if last_use.get(v, -1) <= i:
                cur -= live.pop(v)
        for v in eqn.outvars:
            if isinstance(v, jcore.Var) and last_use.get(v, -1) > i:
                live[v] = _aval_bytes(v.aval)
        # (outputs were already added to cur; reconcile)
        cur = sum(live.values())
        peak = max(peak, cur)

    return flops, traffic, peak, largest


def profile_fn(fn: Callable, *args, weight_args: tuple[int, ...] = ()) -> TraceProfile:
    """Trace ``fn(*args)`` abstractly and profile its jaxpr.

    ``weight_args``: indices of positional args that are model weights
    (their residuals are classified as weight-derived).
    """
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr
    weight_vars: set = set()
    flat_idx = 0
    flat_args, _ = jax.tree.flatten(args)
    # invars correspond to flattened args
    arg_positions: list[int] = []
    for pos, a in enumerate(args):
        n = len(jax.tree.leaves(a))
        arg_positions.extend([pos] * n)
    for v, pos in zip(jaxpr.invars, arg_positions):
        if pos in weight_args:
            weight_vars.add(v)

    ops: list[OpRecord] = []
    resid: dict = {}
    flops, traffic, peak, largest = _walk(
        jaxpr, weight_vars=weight_vars, mult=1.0, ops=ops, resid=resid
    )
    r_act = sum(b for k, b in resid.values() if k == "a")
    r_w = sum(b for k, b in resid.values() if k == "w")
    return TraceProfile(
        ops=ops,
        peak_live_bytes=int(peak),
        total_flops=float(flops),
        total_bytes=int(traffic),
        residual_act_bytes=int(r_act),
        residual_weight_bytes=int(r_w),
        largest_op_bytes=int(largest),
    )


# ---------------------------------------------------------------------------
# Block-level profile: what the cost/memory models consume
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockProfile:
    """Per-superblock forward statistics for one microbatch."""

    flops_fwd: float
    hbm_bytes_fwd: float
    act_residual_bytes: int  # saved residuals under 'none' policy
    boundary_bytes: int  # block input (B,S,D) — the 'checkpoint'/'swap' residual
    peak_transient_bytes: int  # workspace while computing the block

    @property
    def flops_bwd(self) -> float:
        return 2.0 * self.flops_fwd  # standard dL/dx + dL/dw cost

    @property
    def flops_recompute(self) -> float:
        return self.flops_fwd


def profile_superblock(cfg, batch: int, seq: int) -> BlockProfile:
    """Profile one superblock forward at (batch, seq) per microbatch=1."""
    import jax.numpy as jnp

    from repro.models import model as M
    from repro.models.layers import init_tree  # noqa: F401 (abstract only)

    defs = M.param_defs(cfg)["blocks"]
    one = jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape[1:], jnp.dtype(d.dtype)),
        defs,
        is_leaf=lambda x: hasattr(x, "shape") and not hasattr(x, "aval"),
    )
    x = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))

    def fwd(params, x):
        out, aux = M.apply_superblock(params, x, cfg)
        return out

    prof = profile_fn(fwd, one, x, weight_args=(0,))
    boundary = int(np.prod([batch, seq, cfg.d_model])) * jnp.dtype(cfg.dtype).itemsize
    return BlockProfile(
        flops_fwd=prof.total_flops,
        hbm_bytes_fwd=prof.total_bytes,
        act_residual_bytes=prof.residual_act_bytes,
        boundary_bytes=boundary,
        peak_transient_bytes=prof.peak_live_bytes,
    )
