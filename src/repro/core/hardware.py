"""Hardware descriptions used by the cost models and the roofline analysis.

The TPU v5e entry is the production target (constants fixed by the
assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI). The paper's
GPU testbeds are included so the benchmark harness can re-run ProTrain's own
planner search under the paper's conditions (Tables 2-4) and compare against
the paper's reported numbers.
"""
from __future__ import annotations

import dataclasses


# Shared capacity fractions (planner + serving; docs/cost_model.md §1).
# HBM_CAPACITY_FRACTION is the usable slice of a chip's HBM the planners
# budget against — the remainder absorbs XLA's allocator slack, collective
# scratch, and fragmentation. It is the single source of truth for both the
# training search (core/autotuner.search capacity default, launch/dryrun's
# feasibility flag) and the serving planner (core/serve_plan).
HBM_CAPACITY_FRACTION = 0.92
# SERVE_RESIDENT_HEADROOM is serving-specific: the fraction of the *budget*
# that weights + KV cache may fill while still keeping everything resident.
# The reserve covers what the serve memory estimate does not enumerate —
# decode workspace, logits, and growth between planning and admission
# (scheduler admits until pages run out). Above this line the planner starts
# trading residency for host pages / ZeRO-sharded weights.
SERVE_RESIDENT_HEADROOM = 0.75


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # bf16/fp16 FLOP/s per chip
    hbm_bytes: float  # device memory per chip
    hbm_bw: float  # B/s per chip
    ici_bw: float  # B/s per link, intra-pod interconnect (ICI / NVLink)
    host_bw: float  # B/s device<->host (PCIe / host DMA)
    dcn_bw: float  # B/s per chip across pods (data-center network)
    host_mem_bytes: float  # host DRAM available for offload, per host
    chips_per_host: int = 4
    # Achievable fractions (dialed in from experience; exposed for calibration)
    flops_efficiency: float = 0.55  # MFU ceiling for dense matmul pipelines
    mem_efficiency: float = 0.8
    coll_efficiency: float = 0.85
    host_flops: float = 2.0e12  # host-side update throughput (fused CPU Adam analogue)
    # Capacity fractions (see module constants above for semantics); fields so
    # a HardwareSpec can be re-calibrated per deployment without touching the
    # shared defaults.
    hbm_capacity_fraction: float = HBM_CAPACITY_FRACTION
    serve_resident_headroom: float = SERVE_RESIDENT_HEADROOM

    def matmul_time(self, flops: float) -> float:
        return flops / (self.peak_flops * self.flops_efficiency)

    def hbm_time(self, nbytes: float) -> float:
        return nbytes / (self.hbm_bw * self.mem_efficiency)

    def capacity_bytes(self) -> float:
        """Plannable HBM per chip — the Eq. 1 M_capacity both the training
        search and the serving planner constrain against."""
        return self.hbm_bytes * self.hbm_capacity_fraction


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bytes=16e9,
    hbm_bw=819e9,
    ici_bw=50e9,
    host_bw=25e9,
    dcn_bw=12.5e9,
    host_mem_bytes=512e9,
)

# Paper testbeds (Section 5.1), for reproducing Tables 2-4 / Figs 3-6.
RTX_3090 = HardwareSpec(
    name="rtx-3090",
    peak_flops=71e12,  # fp16 w/ fp32 accumulate
    hbm_bytes=24e9,
    hbm_bw=936e9,
    ici_bw=15.8e9,  # no NVLink: collectives ride PCIe 3
    host_bw=15.8e9,  # PCIe 3 x16
    dcn_bw=12.5e9,  # 100 Gb IB (paper section 5.5)
    host_mem_bytes=384e9,
    chips_per_host=4,
    host_flops=0.6e12,  # 24-core Xeon Silver, fused CPU Adam
)

A100_80G = HardwareSpec(
    name="a100-80g",
    peak_flops=312e12,
    hbm_bytes=80e9,
    hbm_bw=2039e9,
    ici_bw=300e9,  # NVLink 3.0
    host_bw=31.5e9,  # PCIe 4 x16
    dcn_bw=12.5e9,
    host_mem_bytes=1e12,
    chips_per_host=4,
    host_flops=2.5e12,  # 112-core Platinum 8480+
)

# Local-host CPU calibration for the fidelity harness and example plan
# summaries (benchmarks/estimator_fidelity.py, examples/train_lm.py): one
# shared set of constants so the example's printed estimates and the CI
# drift gate's predictions come from the same oracle.
LOCAL_CPU_HW = HardwareSpec(
    name="cpu-host",
    peak_flops=5e10,
    hbm_bytes=32e9,
    hbm_bw=20e9,
    ici_bw=10e9,
    host_bw=10e9,
    dcn_bw=1e9,
    host_mem_bytes=32e9,
)

HARDWARE = {h.name: h for h in (TPU_V5E, RTX_3090, A100_80G)}


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Logical mesh geometry + per-axis bandwidth class."""

    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        return self.shape[self.axes.index(name)] if name in self.axes else 1

    @property
    def zero_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def zero_degree(self) -> int:
        n = 1
        for a in self.zero_axes:
            n *= self.axis_size(a)
        return n

    @property
    def tp_degree(self) -> int:
        return self.axis_size("model")

    def gather_bw(self, hw: HardwareSpec) -> float:
        """Effective per-chip bandwidth for a ZeRO all-gather.

        Ring all-gather over the slowest participating axis dominates; when
        the ``pod`` axis participates the DCN leg is the bottleneck.
        """
        if "pod" in self.axes and self.axis_size("pod") > 1:
            return hw.dcn_bw * hw.coll_efficiency
        return hw.ici_bw * hw.coll_efficiency


SINGLE_POD = MeshSpec((16, 16), ("data", "model"))
MULTI_POD = MeshSpec((2, 16, 16), ("pod", "data", "model"))
