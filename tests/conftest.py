"""Suite-wide setup: jax API compat + hypothesis fallback.

Must run before any test module imports, hence conftest:
  * ensure_jax_compat() lets the explicit-sharding call sites
    (jax.sharding.AxisType, make_mesh(axis_types=...)) run on older jaxlib;
  * when the declared `hypothesis` test dep is absent (hermetic CI image),
    the deterministic stub in repro.testing keeps the property suites running
    instead of failing collection.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.compat import ensure_jax_compat

ensure_jax_compat()

try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import hypothesis_stub

    hypothesis_stub.install()
