"""Telemetry subsystem tests (repro.obs): registry/tracer/logger units, the
Chrome-trace export schema, the two load-bearing system properties —
telemetry changes no jitted program (HLO identity) and costs <5% of a toy
step when enabled — and the end-to-end smoke (20-step drift report in band,
every documented metric live, docs table in sync)."""
import importlib.util
import json
import pathlib
import sys
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import build_workload
from repro.core.hardware import LOCAL_CPU_HW, MeshSpec
from repro.core.plan import MemoryPlan
from repro.launch.mesh import make_local_mesh
from repro.obs.metrics import DOCUMENTED_METRICS, MetricsRegistry, quantile
from repro.obs.trace import Tracer
from repro.train import step_builder as SB

REPO = pathlib.Path(__file__).parent.parent


def _load_bench(name: str):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "benchmarks" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# quantile: the shared nearest-rank estimator (engine percentiles use it too)
# ---------------------------------------------------------------------------
def test_quantile_empty_is_zero():
    assert quantile([], 0.5) == 0.0
    assert quantile([], 0.99) == 0.0


def test_quantile_single_sample_every_q():
    """1-sample edge case: every quantile IS the sample (p50 == p99)."""
    for q in (0.0, 0.01, 0.5, 0.99, 1.0):
        assert quantile([7.25], q) == 7.25


def test_quantile_nearest_rank():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert quantile(xs, 0.5) == 2.0
    assert quantile(xs, 0.99) == 4.0
    assert quantile(xs, 0.25) == 1.0


def test_engine_report_percentiles_share_quantile():
    """EngineReport's percentile properties go through the same estimator
    (satellite fix: 0-/1-sample behavior is consistent everywhere)."""
    from repro.serve.engine import EngineReport

    rep = EngineReport(steps=0, generated_tokens=0, finished={}, rejected={},
                       evictions=0, wall_s=0.0, hbm_cache_bytes=0,
                       host_cache_bytes=0, resident_cache_bytes=0)
    assert rep.p50_latency_s == 0.0 and rep.p99_latency_s == 0.0
    rep.request_latency_s[1] = 0.5
    rep.ttft_s[1] = 0.125
    assert rep.p50_latency_s == rep.p99_latency_s == 0.5
    assert rep.p50_ttft_s == rep.p99_ttft_s == 0.125


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("x.count")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("x.gauge")
    g.set(2.0)
    g.set_max(1.0)  # lower: no change
    g.set_max(5.0)
    assert g.value == 5.0
    h = reg.histogram("x.hist")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.total == 10.0 and h.mean == 2.5
    assert h.q(0.5) == 2.0


def test_labeled_series_are_distinct_and_render():
    reg = MetricsRegistry()
    reg.counter("ticks", phase="prefill").inc(2)
    reg.counter("ticks", phase="decode").inc(5)
    reg.counter("ticks").inc(7)
    snap = reg.snapshot()
    assert snap["ticks{phase=prefill}"]["value"] == 2
    assert snap["ticks{phase=decode}"]["value"] == 5
    assert snap["ticks"]["value"] == 7
    assert reg.names() >= {"ticks"}


def test_same_handle_for_same_name_labels():
    reg = MetricsRegistry()
    assert reg.counter("a", k="v") is reg.counter("a", k="v")
    assert reg.counter("a", k="v") is not reg.counter("a", k="w")


def test_null_registry_is_inert():
    from repro.obs.metrics import NULL_REGISTRY

    NULL_REGISTRY.counter("x").inc()
    NULL_REGISTRY.gauge("y").set(1.0)
    NULL_REGISTRY.histogram("z").observe(1.0)
    assert NULL_REGISTRY.snapshot() == {}


# ---------------------------------------------------------------------------
# tracer + Chrome trace export
# ---------------------------------------------------------------------------
def test_spans_nest_and_record():
    tr = Tracer()
    with tr.span("outer", step=1):
        with tr.span("inner"):
            pass
    names = [e["name"] for e in tr.events]
    assert names == ["inner", "outer"]  # inner exits (records) first
    depth = {e["name"]: e["depth"] for e in tr.events}
    assert depth == {"outer": 0, "inner": 1}
    assert tr.events[1]["args"] == {"step": 1}


def test_disabled_tracer_still_measures():
    tr = Tracer(enabled=False)
    with tr.span("t") as sp:
        time.sleep(0.01)
    assert sp.dur_s >= 0.01
    assert tr.events == []


def test_tracer_thread_safety_and_thread_split():
    tr = Tracer()
    # hold all four threads alive together: thread idents are reused after
    # join, and the tid split below needs four distinct ones
    barrier = threading.Barrier(4)

    def work():
        barrier.wait()
        for _ in range(50):
            with tr.span("w"):
                pass
        barrier.wait()

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events) == 200
    doc = tr.to_chrome_trace()
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert len(tids) == 4


def _assert_valid_chrome_trace(doc: dict):
    """The schema contract Perfetto/chrome://tracing require: a JSON object
    with a traceEvents list; every event has a string name and a phase; "X"
    (complete) events carry numeric microsecond ts + dur."""
    assert isinstance(doc, dict) and isinstance(doc["traceEvents"], list)
    assert doc["traceEvents"], "empty trace"
    phases = set()
    for e in doc["traceEvents"]:
        assert isinstance(e["name"], str) and isinstance(e["ph"], str)
        phases.add(e["ph"])
        if e["ph"] == "X":
            assert isinstance(e["ts"], (int, float))
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "i":
            assert e["s"] in ("t", "p", "g")
    assert "X" in phases and "M" in phases  # spans + process/thread names


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("step", step=0):
        with tr.span("fwd"):
            pass
    tr.instant("nan_skip", step=3)
    path = tr.write_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    _assert_valid_chrome_trace(doc)
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"step", "fwd", "nan_skip", "process_name"} <= names


def test_trace_jsonl_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("a", k="v"):
        pass
    path = tr.write_jsonl(str(tmp_path / "trace.jsonl"))
    lines = [json.loads(ln) for ln in open(path)]
    assert lines[0]["name"] == "a" and lines[0]["args"] == {"k": "v"}


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------
def test_logger_keeps_human_line_and_records(tmp_path):
    seen = []
    jl = tmp_path / "log.jsonl"
    log = obs.StructuredLogger("loop", sink=seen.append, jsonl_path=str(jl))
    log.info("step", "[loop] step 3 loss=1.0000 (12 ms)", step=3, loss=1.0)
    assert seen == ["[loop] step 3 loss=1.0000 (12 ms)"]  # byte-identical
    rec = log.records[0]
    assert rec["event"] == "step" and rec["step"] == 3 and rec["loss"] == 1.0
    disk = json.loads(jl.read_text().splitlines()[0])
    assert disk["event"] == "step" and disk["level"] == "info"
    log.close()


def test_logger_legacy_callable_surface():
    """train_loop(log=my_list.append) still works: as_logger wraps plain
    callables, and a StructuredLogger is itself a Callable[[str], None]."""
    seen = []
    log = obs.as_logger(seen.append)
    log("[loop] resumed from checkpoint step 5")
    assert seen == ["[loop] resumed from checkpoint step 5"]
    assert log.records[0]["event"] == "log"
    assert obs.as_logger(log) is log  # passthrough, no double wrap


def test_logger_min_level_filters():
    seen = []
    log = obs.StructuredLogger("x", sink=seen.append, min_level="warning")
    log.info("quiet", "nope")
    log.warning("loud", "yep")
    assert seen == ["yep"] and len(log.records) == 1


# ---------------------------------------------------------------------------
# telemetry handle plumbing
# ---------------------------------------------------------------------------
def test_use_telemetry_scopes_default():
    assert obs.current_telemetry() is obs.NULL_TELEMETRY
    tel = obs.Telemetry()
    with obs.use_telemetry(tel):
        assert obs.current_telemetry() is tel
    assert obs.current_telemetry() is obs.NULL_TELEMETRY


def test_null_telemetry_is_fully_inert():
    tel = obs.NULL_TELEMETRY
    assert not tel.enabled
    with tel.tracer.span("x"):
        tel.registry.counter("c").inc()
    assert tel.tracer.events == [] and tel.registry.snapshot() == {}


# ---------------------------------------------------------------------------
# system property: telemetry never changes the jitted program
# ---------------------------------------------------------------------------
def _micro_train_setup():
    cfg = reduced(ARCHS["llama3-405b"], num_layers=2, d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=32)
    shape = ShapeConfig("obs_hlo", 32, 2, "train")
    mesh = make_local_mesh()
    w = build_workload(cfg, shape, MeshSpec((1, 1), ("data", "model")),
                       LOCAL_CPU_HW)
    plan = MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks)
    return cfg, plan, mesh, shape, w


def test_hlo_identical_with_and_without_telemetry():
    """All instrumentation is host-side: building (and lowering) the train
    step under an installed, fully-enabled telemetry handle produces the
    byte-identical program to building it with telemetry off."""
    cfg, plan, mesh, shape, _ = _micro_train_setup()
    text_off = SB.build_train_step(cfg, plan, mesh, shape).lower().as_text()
    with obs.use_telemetry(obs.Telemetry()):
        text_on = SB.build_train_step(cfg, plan, mesh, shape).lower().as_text()
    assert text_on == text_off


def test_sync_inventory_recorded_at_build():
    cfg, plan, mesh, shape, _ = _micro_train_setup()
    tel = obs.Telemetry(trace=False)
    with obs.use_telemetry(tel):
        SB.build_train_step(cfg, plan, mesh, shape)
    snap = tel.registry.snapshot()
    grad = snap["sync.wire_bytes_per_step{op=grad_sync,strategy=xla}"]
    assert grad["value"] > 0
    # fp32 payload under grad_compress="none"
    assert snap["sync.wire_payload{strategy=xla}"]["value"] == 4


# ---------------------------------------------------------------------------
# system property: enabled-path overhead < 5% of a toy step
# ---------------------------------------------------------------------------
def test_enabled_overhead_under_5pct_of_toy_step():
    """The full per-step telemetry work (span + histogram + gauges +
    counters + device-memory watermark + drift observation) costs < 5% of
    one 8-layer-toy training step."""
    cfg = reduced(ARCHS["llama3-405b"], num_layers=8, d_model=128, d_ff=512,
                  vocab_size=1024, num_heads=4, num_kv_heads=4, head_dim=32)
    shape = ShapeConfig("obs_overhead", 64, 2, "train")
    mesh = make_local_mesh()
    w = build_workload(cfg, shape, MeshSpec((1, 1), ("data", "model")),
                       LOCAL_CPU_HW)
    plan = MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks)
    art = SB.build_train_step(cfg, plan, mesh, shape)
    from repro.data.pipeline import SyntheticTokenPipeline

    pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
    state = art.init(jax.random.PRNGKey(0))
    jfn = jax.jit(art.fn)
    batch = pipe.next_sync()
    jfn(state, batch)[1]["loss"].block_until_ready()  # compile
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        _, m = jfn(state, batch)
        m["loss"].block_until_ready()
        times.append(time.perf_counter() - t0)
    step_s = sorted(times)[1]  # median of 3

    tel = obs.Telemetry()
    mon = obs.DriftMonitor(w, plan, registry=tel.registry)
    reg, tracer = tel.registry, tel.tracer
    h = reg.histogram("train.step_time_s")
    g_loss = reg.gauge("train.loss")
    g_mem = reg.gauge("train.device_mem_watermark_bytes")
    c_steps = reg.counter("train.steps")
    n = 200
    t0 = time.perf_counter()
    for i in range(n):
        with tracer.span("train.step", step=i):
            pass
        h.observe(step_s)
        c_steps.inc()
        g_loss.set(1.0)
        mem, src = obs.device_memory_watermark()
        g_mem.set_max(mem)
        mon.observe_step(step_s, mem, mem_source=src)
    per_step_overhead = (time.perf_counter() - t0) / n
    assert per_step_overhead < 0.05 * step_s, (
        f"telemetry overhead {per_step_overhead * 1e6:.0f}us/step vs step "
        f"{step_s * 1e3:.1f}ms")


# ---------------------------------------------------------------------------
# end-to-end: drift report in band, trace loads, docs table in sync
# ---------------------------------------------------------------------------
def test_telemetry_smoke_end_to_end(tmp_path, monkeypatch):
    """The CI telemetry-smoke gate as a test: 20 real train steps + a paged
    serve load under one registry; drift ratios inside the 3.0 band; the
    exported trace.json is valid Chrome-trace JSON; every documented metric
    exists."""
    mod = _load_bench("telemetry_smoke")
    monkeypatch.setattr(sys, "argv",
                        ["telemetry_smoke", "--out-dir", str(tmp_path)])
    assert mod.main() == 0
    drift = json.loads((tmp_path / "drift_report.json").read_text())
    assert drift["kind"] == "drift_report" and drift["steps"] == 20
    assert drift["ok"]
    for dim in ("runtime", "memory"):
        assert drift[dim]["in_band"]
        assert 1 / 3.0 <= drift[dim]["ratio"] <= 3.0
    with open(tmp_path / "trace.json") as f:
        _assert_valid_chrome_trace(json.load(f))
    snap = json.loads((tmp_path / "telemetry_metrics.json").read_text())
    assert snap  # non-empty registry snapshot rides along


def test_documented_metrics_match_docs_table():
    """docs/observability.md's metric table and DOCUMENTED_METRICS move
    together: every name in the tuple appears in the doc, and every
    `name`-style metric row in the doc's table exists in the tuple."""
    doc = (REPO / "docs" / "observability.md").read_text()
    for name in DOCUMENTED_METRICS:
        assert f"`{name}`" in doc, f"{name} missing from docs/observability.md"
