"""Hypothesis property tests on system invariants beyond test_core's plan
properties: MoE dispatch conservation, SSD chunking equivalence, memory-model
replay consistency, roofline parser robustness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import Mamba2Config, ModelConfig, MoeConfig


# ---------------------------------------------------------------------------
# Mamba-2 SSD: chunked == sequential recurrence, for any chunk size
# ---------------------------------------------------------------------------
@given(
    s=st.integers(2, 48),
    chunk=st.sampled_from([1, 2, 4, 8, 64]),
    h=st.sampled_from([1, 2]),
)
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_equals_sequential(s, chunk, h):
    from repro.models.mamba2 import ssd_chunked

    key = jax.random.PRNGKey(s * 7 + chunk)
    p, n, b = 4, 8, 2
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    cm = jax.random.normal(ks[0], (b, s, n), jnp.float32)

    y_chunk, st_chunk = ssd_chunked(x, dt, a, bm, cm, chunk_size=chunk)

    # sequential oracle: h_t = exp(a dt_t) h_{t-1} + dt_t B_t x_t; y = C_t h_t
    state = np.zeros((b, h, p, n), np.float64)
    ys = []
    xn, dtn, an = np.asarray(x, np.float64), np.asarray(dt, np.float64), np.asarray(a, np.float64)
    bn, cn = np.asarray(bm, np.float64), np.asarray(cm, np.float64)
    for t in range(s):
        decay = np.exp(an * dtn[:, t])  # (b, h)
        inp = np.einsum("bn,bhp->bhpn", bn[:, t], xn[:, t] * dtn[:, t][..., None])
        state = state * decay[:, :, None, None] + inp
        ys.append(np.einsum("bn,bhpn->bhp", cn[:, t], state))
    y_ref = np.stack(ys, axis=1)  # (b, s, h, p)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float64), y_ref, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk, np.float64), state, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# MoE dispatch: combine weights conserve <= 1 per token; outputs bounded
# ---------------------------------------------------------------------------
@given(
    t=st.integers(4, 32),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    cf=st.sampled_from([0.5, 1.0, 8.0]),
)
@settings(max_examples=20, deadline=None)
def test_moe_combine_weights_conserved(t, e, k, cf):
    from repro.models.moe import apply_moe, moe_defs
    from repro.models.layers import init_tree

    k = min(k, e)
    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, mlp="gelu",
        moe=MoeConfig(num_experts=e, top_k=k, capacity_factor=cf),
        dtype="float32",
    )
    params = init_tree(moe_defs(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda p: p.astype(jnp.float32) if p.dtype == jnp.bfloat16 else p, params)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, t, 16), jnp.float32)
    out, aux = apply_moe(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0
    # with generous capacity, no token may be dropped: perturbing one expert's
    # weights must affect the output (all experts engaged through routing)
    if cf >= 8.0:
        p2 = dict(params)
        p2["w2"] = params["w2"] + 1.0
        out2, _ = apply_moe(p2, x, cfg)
        assert float(jnp.abs(out2 - out).max()) > 0


# ---------------------------------------------------------------------------
# memory model: trajectory replay internally consistent for random plans
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def workload():
    from repro.configs import get_config, TRAIN_4K
    from repro.core import SINGLE_POD, TPU_V5E, build_workload

    return build_workload(get_config("starcoder2-15b"), TRAIN_4K, SINGLE_POD, TPU_V5E)


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_memory_trajectory_peak_is_max(workload, data):
    from repro.core import estimate_memory
    from repro.core.plan import MemoryPlan

    nc, nb = workload.n_chunks, workload.n_blocks
    n_persist = data.draw(st.integers(0, nc))
    n_host = data.draw(st.integers(0, nc - n_persist))
    n_swap = data.draw(st.integers(0, nb // 2))
    n_ckpt = data.draw(st.integers(0, nb - n_swap))
    ub = data.draw(st.sampled_from([1, 2, 4]))
    plan = MemoryPlan(nc, nb, n_persist=n_persist, n_host=n_host, n_swap=n_swap,
                      n_checkpoint=n_ckpt, microbatch=ub)
    mem = estimate_memory(workload, plan)
    assert mem.peak >= max(mem.trajectory) - 1e-6
    assert mem.peak > 0
    assert all(v >= 0 for v in mem.trajectory)


@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_runtime_positive_and_bounded(workload, data):
    from repro.core import estimate_runtime
    from repro.core.plan import MemoryPlan

    nc, nb = workload.n_chunks, workload.n_blocks
    plan = MemoryPlan(
        nc, nb,
        n_persist=data.draw(st.integers(0, nc)),
        n_checkpoint=data.draw(st.integers(0, nb)),
        microbatch=data.draw(st.sampled_from([1, 2, 4])),
    )
    rt = estimate_runtime(workload, plan)
    assert 0 < rt.t_iteration < 3600
    assert rt.t_iteration + 1e-9 >= rt.t_fwd


# ---------------------------------------------------------------------------
# roofline parser robustness: arbitrary shape strings never crash
# ---------------------------------------------------------------------------
@given(st.text(alphabet="fbsu0123456789[],(){}x ", max_size=60))
@settings(max_examples=100, deadline=None)
def test_shape_bytes_never_crashes(s):
    from repro.launch.roofline import _shape_bytes

    assert _shape_bytes(s) >= 0
