"""Assertions tying the reproduction to the paper's claims (EXPERIMENTS.md
§Paper-claims): these run the planner under the paper's GPU testbeds and
check the qualitative structure the paper reports."""
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.paper_models import PAPER_MODELS
from repro.core import build_workload, estimate_memory, estimate_runtime, search
from repro.core.baselines import BASELINES
from repro.core.hardware import A100_80G, RTX_3090, MeshSpec

GPU1 = MeshSpec((1,), ("data",))
GPU4 = MeshSpec((4,), ("data",))


def _throughput(cfg, batch, hw, planner):
    shape = ShapeConfig("b", 1024, batch, "train")
    w = build_workload(cfg, shape, GPU4, hw)
    cap = hw.hbm_bytes * 0.92
    if planner == "protrain":
        res = search(w, capacity_bytes=cap)
        return res.runtime.tokens_per_second if res.feasible else 0.0
    plan = BASELINES[planner](w, cap)
    if estimate_memory(w, plan).peak >= cap:
        return 0.0
    return estimate_runtime(w, plan).tokens_per_second


def test_protrain_trains_larger_models_than_baselines_single_3090():
    """Table 2: ProTrain > DeepSpeed/FSDP max size on one RTX 3090."""
    from benchmarks.paper_tables import max_trainable_size

    pro = max_trainable_size(RTX_3090, GPU1, "protrain")
    ds = max_trainable_size(RTX_3090, GPU1, "deepspeed")
    fsdp = max_trainable_size(RTX_3090, GPU1, "fsdp")
    assert pro >= 20.0, f"ProTrain should train >=20B on 24GB+384GB host (got {pro})"
    assert pro > 1.5 * ds, (pro, ds)
    assert pro > fsdp


def test_protrain_not_slower_than_baselines():
    """Fig. 3: ProTrain throughput >= each baseline (same hardware/model)."""
    for name in ("gpt2-10b", "llama-13b"):
        cfg = PAPER_MODELS[name]
        pro = max(_throughput(cfg, b, A100_80G, "protrain") for b in (8, 64))
        for other in ("deepspeed", "colossalai", "fsdp"):
            base = max(_throughput(cfg, b, A100_80G, other) for b in (8, 64))
            assert pro >= base * 0.999, (name, other, pro, base)


def test_table4_batch_size_shrinks_persistence():
    """Table 4 rows A->B: larger batch forces fewer persistent chunks."""
    cfg = PAPER_MODELS["gpt2-1b"]
    hw = RTX_3090
    plans = {}
    for batch in (8, 64):
        w = build_workload(cfg, ShapeConfig("b", 1024, batch, "train"), GPU4, hw)
        plans[batch] = search(w).plan
    assert plans[64].n_persist < plans[8].n_persist


def test_table4_a100_avoids_memory_savings_for_small_model():
    """Table 4 row C: 1B model at batch 64 on A100 needs no ckpt/offload."""
    cfg = PAPER_MODELS["gpt2-1b"]
    w = build_workload(cfg, ShapeConfig("b", 1024, 64, "train"), GPU4, A100_80G)
    plan = search(w).plan
    assert plan.n_checkpoint == 0 and plan.n_swap == 0 and plan.n_host == 0


def test_table3_large_model_requires_offload():
    """Table 3: GPT2-20B on 4xA100 is infeasible without offloading."""
    cfg = PAPER_MODELS["gpt2-20b"]
    w = build_workload(cfg, ShapeConfig("b", 1024, 8, "train"), GPU4, A100_80G)
    no_off = search(w, allow_host=False)
    with_off = search(w, allow_host=True)
    assert not no_off.feasible
    assert with_off.feasible


def test_fig5_overlap_matters():
    """Fig. 5: un-overlapping the host update costs >10% at batch >= 8."""
    cfg = PAPER_MODELS["gpt2-10b"]
    w = build_workload(cfg, ShapeConfig("b", 1024, 8, "train"), GPU4, RTX_3090)
    res = search(w)
    rt = res.runtime
    t_no_overlap = rt.t_fwd + rt.t_bwd + rt.t_gpu_optim + rt.t_cpu_optim
    if rt.t_cpu_optim > 0:
        assert t_no_overlap > 1.1 * rt.t_iteration


def test_memory_estimator_tracks_xla():
    """Fig. 6 (bottom) analogue: predicted peak memory vs XLA buffer
    assignment across plan variants — within 2x absolute and correctly
    ordered (the search only needs ordering + a safety margin)."""
    from benchmarks.estimator_fidelity import memory_fidelity

    rows = {r["plan"]: r for r in memory_fidelity()}
    for r in rows.values():
        assert 0.5 <= r["ratio"] <= 2.0, r
    # orderings the planner relies on
    assert rows["ckpt_all"]["predicted_gb"] < rows["ckpt_half"]["predicted_gb"] < rows["resident"]["predicted_gb"]
    assert rows["ckpt_all"]["xla_gb"] < rows["ckpt_half"]["xla_gb"] < rows["resident"]["xla_gb"]
    assert rows["ubatch2"]["predicted_gb"] < rows["resident"]["predicted_gb"]
    assert rows["ubatch2"]["xla_gb"] < rows["resident"]["xla_gb"]


def test_runtime_estimator_absolute_sanity():
    """Runtime estimator vs measured CPU wall time for the fully-resident
    plan (the only contrast where a loaded 1-core container is a meaningful
    oracle — recompute plans *speed up* on CPU via cache locality, see
    EXPERIMENTS.md). Within 2x."""
    from benchmarks.estimator_fidelity import runtime_fidelity

    rows = {r["plan"]: r for r in runtime_fidelity(steps=2)}
    r = rows["resident"]
    assert 0.5 <= r["modeled_s"] / max(r["measured_s"], 1e-9) <= 2.0, r
