"""Substrate tests: optimizer, data pipeline, checkpointing, train loop,
step builder integration (plan variants on a tiny model)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core.plan import MemoryPlan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.ckpt.checkpoint import CheckpointManager
from repro.optim.adam import AdamConfig, adam_update, cosine_schedule, init_opt_state
from repro.train.loop import LoopConfig, train_loop
from repro.train.step_builder import build_train_step, plan_runs

KEY = jax.random.PRNGKey(0)
TINY = reduced(ARCHS["llama3-405b"])
SHAPE = ShapeConfig("tiny", 64, 4, "train")


def local_mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adam_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0], jnp.float32)}
    opt = init_opt_state(params)
    cfg = AdamConfig(lr=0.1, grad_clip=100.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adam_update(params, grads, opt, cfg, cfg.lr)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adam_master_weights_preserve_precision():
    """bf16 params + tiny updates: master fp32 must accumulate what bf16 cannot."""
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    opt = init_opt_state(params)
    cfg = AdamConfig(lr=1e-5, grad_clip=1e9)
    g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    for _ in range(10):
        params, opt, _ = adam_update(params, g, opt, cfg, cfg.lr)
    drift = np.asarray(opt["master"]["w"]) - 1.0
    assert np.all(drift != 0.0)  # fp32 master moved even when bf16 rounds away


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-4


def test_grad_clip():
    from repro.optim.adam import clip_by_global_norm

    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert abs(float(total) - 1.0) < 1e-3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    p1 = SyntheticTokenPipeline(TINY, SHAPE, seed=7)
    b1 = [p1.next_sync() for _ in range(3)]
    # resume from state after 1 batch
    p2 = SyntheticTokenPipeline(TINY, SHAPE, seed=7)
    p2.next_sync()
    state = p2.state()
    p3 = SyntheticTokenPipeline.from_state(TINY, SHAPE, state)
    b3 = p3.next_sync()
    np.testing.assert_array_equal(np.asarray(b1[1]["tokens"]), np.asarray(b3["tokens"]))


def test_pipeline_prefetch_thread():
    p = SyntheticTokenPipeline(TINY, SHAPE, seed=1, prefetch=2)
    it = iter(p)
    a = next(it)
    b = next(it)
    assert a["tokens"].shape == (SHAPE.global_batch, SHAPE.seq_len)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    p.stop()


def test_pipeline_labels_are_shifted_tokens():
    p = SyntheticTokenPipeline(TINY, SHAPE, seed=3)
    b = p.next_sync()
    np.testing.assert_array_equal(
        np.asarray(b["tokens"])[:, 1:], np.asarray(b["labels"])[:, :-1]
    )


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"a": jnp.arange(10, dtype=jnp.float32), "nested": {"b": jnp.ones((3, 3))}}
    mgr.save(5, state, extra={"data_step": 5}, sync=True)
    specs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extra = mgr.restore(5, specs)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(state["a"]))
    assert extra["data_step"] == 5


def test_checkpoint_atomicity_no_partial_reads(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    # a stale tmp dir (crashed save) must be invisible
    os.makedirs(tmp_path / "step_9.tmp")
    assert mgr.latest_step() is None
    mgr.save(1, {"x": jnp.zeros(4)}, sync=True)
    assert mgr.latest_step() == 1


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full(2, s)}, sync=True)
    assert mgr.steps() == [3, 4]


def test_checkpoint_elastic_restore_different_sharding(tmp_path):
    """Save unsharded, restore onto an explicit 1x1 mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, state, sync=True)
    mesh = local_mesh()
    spec = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32,
                                      sharding=NamedSharding(mesh, P("data", None)))}
    restored, _ = mgr.restore(1, spec)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))


# ---------------------------------------------------------------------------
# plan -> runs layout
# ---------------------------------------------------------------------------
def test_plan_runs_cover_all_repeats():
    plan = MemoryPlan(n_chunks=12, n_blocks=10, n_persist=3, n_buffer=2,
                      n_host=4, n_swap=2, n_checkpoint=5)
    runs = plan_runs(plan, 10)
    assert sum(r.length for r in runs) == 10
    # persist chunks are at the front (chunks 1,2 -> repeats 0,1)
    assert runs[0].placement == "persist"
    # host chunks at the back
    assert runs[-1].placement == "host"
    # swap blocks first
    assert runs[0].act_policy == "swap"


def test_runs_merge_adjacent_same_policy():
    plan = MemoryPlan(n_chunks=10, n_blocks=8, n_persist=0)
    runs = plan_runs(plan, 8)
    assert len(runs) == 1 and runs[0].length == 8


# ---------------------------------------------------------------------------
# end-to-end loop with fault tolerance
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_artifacts():
    mesh = local_mesh()
    plan = MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4)
    return build_train_step(TINY, plan, mesh, SHAPE, adam=AdamConfig(lr=3e-3))


def test_train_loop_runs_and_learns(tiny_artifacts, tmp_path):
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    mgr = CheckpointManager(str(tmp_path))
    res = train_loop(tiny_artifacts, pipe, mgr,
                     LoopConfig(total_steps=30, checkpoint_every=10, log_every=100))
    assert res.steps_run == 30
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])
    assert mgr.latest_step() == 30


def test_train_loop_resumes_from_checkpoint(tiny_artifacts, tmp_path):
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    mgr = CheckpointManager(str(tmp_path))
    train_loop(tiny_artifacts, pipe, mgr,
               LoopConfig(total_steps=10, checkpoint_every=5, log_every=100))
    # second run picks up at step 10 and continues to 15
    pipe2 = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    res2 = train_loop(tiny_artifacts, pipe2, mgr,
                      LoopConfig(total_steps=15, checkpoint_every=5, log_every=100))
    assert res2.resumed_from == 10
    assert res2.steps_run == 5
    assert pipe2.step >= 15  # data state restored, not restarted
