"""Gradient compression tests (shard_map collectives on a multi-device mesh
require >1 device; these run the math path on a 1-device mesh and assert the
error-feedback invariant)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import bf16_all_reduce, compressed_all_reduce, _quantize_int8, _dequantize_int8


def mesh1():
    return jax.make_mesh((1,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))


def test_bf16_all_reduce_identity_on_one_device():
    x = jnp.linspace(-2, 2, 64, dtype=jnp.float32)
    out = bf16_all_reduce(x, mesh1())
    np.testing.assert_allclose(np.asarray(out), np.asarray(x.astype(jnp.bfloat16), np.float32),
                               atol=2e-2)


def test_quantize_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,), jnp.float32)
    q, scale = _quantize_int8(x)
    back = _dequantize_int8(q, scale)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= float(scale.max()) / 2 + 1e-6  # half-step rounding


def test_compressed_all_reduce_error_feedback():
    """Residual + sent == input (+ prior residual): nothing is lost."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1000,), jnp.float32)
    err0 = jnp.zeros_like(x)
    avg, err1 = compressed_all_reduce(x, err0, mesh1())
    # on 1 device: avg + err == x exactly (modulo float assoc)
    np.testing.assert_allclose(np.asarray(avg + err1), np.asarray(x), atol=1e-4)
    # feeding the error back converges toward the true mean over steps
    avg2, err2 = compressed_all_reduce(x, err1, mesh1())
    assert float(jnp.abs(err2).mean()) <= float(jnp.abs(err1).mean()) + 1e-3


# ---------------------------------------------------------------------------
# manual reduce-scatter primitives (ISSUE-3): shard_map on the real mesh
# ---------------------------------------------------------------------------
import math

import pytest

from repro.compat import shard_map
from repro.dist.collectives import (
    manual_bf16_reduce_scatter,
    manual_int8_ef_reduce_scatter,
    manual_reduce_scatter,
)

N_DEV = len(jax.devices())
needs_multi = pytest.mark.skipif(N_DEV < 2, reason="reduce-scatter needs >1 device")


def data_mesh():
    return jax.make_mesh((N_DEV,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _run_rs(fn, local_inputs, in_specs, out_specs):
    mesh = data_mesh()
    return jax.jit(shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs,
                             check=False))(*local_inputs)


@needs_multi
def test_int8_ef_reduce_scatter_each_owner_gets_the_mean_shard():
    from jax.sharding import PartitionSpec as P

    rows = 2 * N_DEV
    g = jax.random.normal(jax.random.PRNGKey(0), (N_DEV, rows, 6), jnp.float32)
    err0 = jnp.zeros((N_DEV, rows // N_DEV, 6), jnp.float32)

    def body(gl, el):
        s, ne = manual_int8_ef_reduce_scatter(gl[0], el[0], ("data",), 0)
        return s[None], ne[None]

    shards, errs = _run_rs(
        body, (g, err0),
        (P("data", None, None), P("data", None, None)),
        (P("data", None, None), P("data", None, None)))
    got = np.asarray(shards).reshape(rows, 6)
    want = np.asarray(g).mean(0)
    step = np.abs(np.asarray(g)).max() / 127.0
    assert np.abs(got - want).max() <= step  # within one quantization step
    # per-device EF is nonzero (quantization dropped something) and bounded
    e = np.asarray(errs)
    assert e.shape == (N_DEV, rows // N_DEV, 6)  # shard-sized residuals
    assert np.abs(e).max() <= step / 2 + 1e-6
    assert np.abs(e).max() > 0


@needs_multi
def test_int8_ef_reduce_scatter_pads_uneven_divisors():
    """Leaves whose dim does not divide the sync extent are padded to the
    next multiple; owners hold the padded shard, reconstruction drops the
    tail (the train-state layout never shards such dims — this keeps the
    primitive composable on its own)."""
    from jax.sharding import PartitionSpec as P

    rows = 2 * N_DEV + 1  # uneven
    pad_rows = math.ceil(rows / N_DEV) * N_DEV
    shard_rows = pad_rows // N_DEV
    g = jax.random.normal(jax.random.PRNGKey(1), (N_DEV, rows, 3), jnp.float32)
    err0 = jnp.zeros((N_DEV, shard_rows, 3), jnp.float32)

    def body(gl, el):
        s, ne = manual_int8_ef_reduce_scatter(gl[0], el[0], ("data",), 0)
        return s[None], ne[None]

    shards, errs = _run_rs(
        body, (g, err0),
        (P("data", None, None), P("data", None, None)),
        (P("data", None, None), P("data", None, None)))
    got = np.asarray(shards).reshape(pad_rows, 3)[:rows]
    want = np.asarray(g).mean(0)
    step = np.abs(np.asarray(g)).max() / 127.0
    assert np.abs(got - want).max() <= step
    assert np.asarray(errs).shape == (N_DEV, shard_rows, 3)


@needs_multi
@pytest.mark.parametrize("rs,tol", [(manual_reduce_scatter, 1e-6),
                                    (manual_bf16_reduce_scatter, 2e-2)])
def test_uncompressed_reduce_scatter_variants(rs, tol):
    from jax.sharding import PartitionSpec as P

    rows = 2 * N_DEV
    g = jax.random.normal(jax.random.PRNGKey(2), (N_DEV, rows, 4), jnp.float32)

    def body(gl):
        return rs(gl[0], ("data",), 0)[None]

    shards = _run_rs(body, (g,), P("data", None, None), P("data", None, None))
    got = np.asarray(shards).reshape(rows, 4)
    np.testing.assert_allclose(got, np.asarray(g).mean(0), atol=tol, rtol=tol)


@needs_multi
def test_int8_reduce_scatter_ef_feedback_reduces_own_shard_error():
    """Feeding the shard residual back biases the next transmission so the
    own-shard contribution converges (EF invariant at shard granularity)."""
    from jax.sharding import PartitionSpec as P

    rows = 2 * N_DEV
    g = jax.random.normal(jax.random.PRNGKey(3), (N_DEV, rows, 5), jnp.float32)
    err = jnp.zeros((N_DEV, rows // N_DEV, 5), jnp.float32)

    def body(gl, el):
        s, ne = manual_int8_ef_reduce_scatter(gl[0], el[0], ("data",), 0)
        return s[None], ne[None]

    mesh = data_mesh()
    f = jax.jit(shard_map(
        body, mesh,
        in_specs=(P("data", None, None), P("data", None, None)),
        out_specs=(P("data", None, None), P("data", None, None)), check=False))
    _, err1 = f(g, err)
    _, err2 = f(g, err1)
    # the EF invariant: transmitted + residual == input + prior residual for
    # the own chunk, so the residual stays bounded rather than accumulating
    assert float(jnp.abs(err2).max()) <= 2 * float(jnp.abs(err1).max()) + 1e-6
