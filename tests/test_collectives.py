"""Gradient compression tests (shard_map collectives on a multi-device mesh
require >1 device; these run the math path on a 1-device mesh and assert the
error-feedback invariant)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import bf16_all_reduce, compressed_all_reduce, _quantize_int8, _dequantize_int8


def mesh1():
    return jax.make_mesh((1,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))


def test_bf16_all_reduce_identity_on_one_device():
    x = jnp.linspace(-2, 2, 64, dtype=jnp.float32)
    out = bf16_all_reduce(x, mesh1())
    np.testing.assert_allclose(np.asarray(out), np.asarray(x.astype(jnp.bfloat16), np.float32),
                               atol=2e-2)


def test_quantize_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,), jnp.float32)
    q, scale = _quantize_int8(x)
    back = _dequantize_int8(q, scale)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= float(scale.max()) / 2 + 1e-6  # half-step rounding


def test_compressed_all_reduce_error_feedback():
    """Residual + sent == input (+ prior residual): nothing is lost."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1000,), jnp.float32)
    err0 = jnp.zeros_like(x)
    avg, err1 = compressed_all_reduce(x, err0, mesh1())
    # on 1 device: avg + err == x exactly (modulo float assoc)
    np.testing.assert_allclose(np.asarray(avg + err1), np.asarray(x), atol=1e-4)
    # feeding the error back converges toward the true mean over steps
    avg2, err2 = compressed_all_reduce(x, err1, mesh1())
    assert float(jnp.abs(err2).mean()) <= float(jnp.abs(err1).mean()) + 1e-3
