"""ISSUE-8: fused Pallas paged-attention decode kernel + fused int8 quantize.

Covers the acceptance criteria:
  * the decode-attention kernel (kernels/paged_attention.py) matches the
    ref.py oracle and the lax page-rebuild path *bitwise* — full-attention,
    SWA ring-wrap, hybrid Jamba, per-slot positions, and a 90-token
    engine-level decode;
  * the fused int8 quantize+pack kernel (kernels/fused_quant.py) matches
    the three-op absmax/round/residual sequence bitwise, including the EF
    residual round-trip, under hypothesis (or the repro.testing stub).

Exactness contract: each comparison jits the oracle as one program so both
sides see identical XLA fusion (the kernel body is always one traced
computation; an op-by-op eager oracle drifts by ~1 ulp from fused
multiply-adds — that drift belongs to the *oracle's* execution mode, not
the kernel). Under that discipline every assertion here is ``diff == 0.0``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.plan import MemoryPlan
from repro.kernels import ref as R
from repro.kernels.fused_quant import fused_quantize_ef
from repro.kernels.paged_attention import paged_attention
from repro.launch.mesh import make_local_mesh
from repro.models import kvcache as KV
from repro.models import model as M
from repro.serve import DecodeEngine, PagedKV, Request, choose_paging, init_paged_cache

KEY = jax.random.PRNGKey(0)

_pa_ref = jax.jit(R.paged_attention_ref)
_fq_ref = jax.jit(R.fused_quantize_ef_ref)


def _paged_inputs(key, b, hq, hkv, s, w, hd, masked_frac=0.2):
    ks = jax.random.split(key, 7)
    q = jax.random.normal(ks[0], (b, 1, hq, hd), jnp.float32)
    kh = jax.random.normal(ks[1], (b, w, hkv, hd), jnp.float32)
    vh = jax.random.normal(ks[2], (b, w, hkv, hd), jnp.float32)
    kc = jax.random.normal(ks[3], (b, s, hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[4], (b, s, hkv, hd), jnp.float32)
    sel = jax.random.bernoulli(ks[5], 0.5, (b, s))
    mask = jnp.where(jax.random.bernoulli(ks[6], 1.0 - masked_frac, (b, s)),
                     0.0, -1e30).astype(jnp.float32)
    return q, kh, vh, kc, vc, sel, mask


# ---------------------------------------------------------------------------
# kernel vs ref.py oracle: synthetic sweeps, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,s,w,psz,hd", [
    (2, 8, 2, 64, 16, 8, 32),    # GQA 4:1, two hot pages
    (1, 4, 4, 32, 8, 8, 16),     # MHA, single hot page
    (3, 6, 3, 48, 24, 8, 64),    # GQA 2:1, three hot pages
    (2, 16, 1, 40, 8, 4, 8),     # MQA, small pages
])
def test_kernel_matches_oracle_bitwise(b, hq, hkv, s, w, psz, hd):
    args = _paged_inputs(jax.random.fold_in(KEY, s + w), b, hq, hkv, s, w, hd)
    out = paged_attention(*args, n_hot=w // psz, interpret=True)
    ref = _pa_ref(*args)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    assert float(jnp.abs(out - ref).max()) == 0.0


def test_kernel_fully_masked_rows_are_neutral():
    """A slot whose every non-causal position is masked must still produce
    finite output (the -1e30 additive mask keeps softmax well-defined) and
    agree with the oracle bitwise."""
    q, kh, vh, kc, vc, sel, _ = _paged_inputs(KEY, 2, 4, 2, 32, 8, 16)
    mask = jnp.where(jnp.arange(32)[None, :] < 1, 0.0, -1e30)
    mask = jnp.broadcast_to(mask, (2, 32)).astype(jnp.float32)
    out = paged_attention(q, kh, vh, kc, vc, sel, mask, n_hot=4, interpret=True)
    ref = _pa_ref(q, kh, vh, kc, vc, sel, mask)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out - ref).max()) == 0.0


# ---------------------------------------------------------------------------
# kernel vs the lax page-rebuild: decode drives through PagedKV.attend
# ---------------------------------------------------------------------------
def _drive_kernel_vs_lax(cfg, B, S, steps, page, hot, per_slot=False):
    """Decode ``steps`` tokens through two PagedKV hooks — the fused kernel
    vs the gather-then-attend lax rebuild — and return the worst logits
    divergence (must be 0.0: both reduce to _masked_decode_attn's op
    sequence)."""
    spec = choose_paging(KV.cache_len(cfg, S), page, hot)
    assert spec.n_cold > 0, "parity must exercise cold pages"
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    io_k = PagedKV(spec, use_kernel=True)
    io_l = PagedKV(spec, use_kernel=False)
    assert io_k.use_kernel and not io_l.use_kernel
    cache_k = init_paged_cache(cfg, B, S, spec)
    cache_l = init_paged_cache(cfg, B, S, spec)
    step_k = jax.jit(lambda c, t, p: KV.decode_step(params, c, t, p, cfg, kv_io=io_k))
    step_l = jax.jit(lambda c, t, p: KV.decode_step(params, c, t, p, cfg, kv_io=io_l))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, steps), 0, cfg.vocab_size)
    worst = 0.0
    for t in range(steps):
        pos = jnp.full((B,), t, jnp.int32) if per_slot else jnp.int32(t)
        lk, cache_k = step_k(cache_k, toks[:, t:t + 1], pos)
        ll, cache_l = step_l(cache_l, toks[:, t:t + 1], pos)
        worst = max(worst, float(jnp.abs(lk - ll).max()))
    return worst


@pytest.mark.parametrize("per_slot", [False, True])
def test_kernel_decode_parity_full_attention(per_slot):
    cfg = reduced(get_config("llama3-405b"))
    diff = _drive_kernel_vs_lax(cfg, B=4, S=64, steps=40, page=8, hot=2,
                                per_slot=per_slot)
    assert diff == 0.0, f"kernel decode diverged from lax rebuild: {diff}"


@pytest.mark.parametrize("hot", [1, 4])
def test_kernel_decode_parity_sliding_window_ring(hot):
    """Mixtral's ring cache, decoded far past the window: the ring wraps and
    the steady-state every-slot-valid mask exercises the stale-row rules the
    kernel's residency select must reproduce."""
    cfg = reduced(get_config("mixtral-8x22b"))
    assert cfg.sliding_window, "config must ring-buffer"
    diff = _drive_kernel_vs_lax(cfg, B=4, S=96, steps=90, page=8, hot=hot)
    assert diff == 0.0, f"SWA kernel decode diverged: {diff}"


def test_kernel_decode_parity_hybrid_mamba_resident():
    """Jamba: only the attention positions route through the kernel; mamba
    state stays O(1)-resident and must be untouched by the kv_io swap."""
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    diff = _drive_kernel_vs_lax(cfg, B=4, S=64, steps=40, page=8, hot=2)
    assert diff == 0.0, f"hybrid kernel decode diverged: {diff}"


def test_engine_level_90_token_decode_resident_matches_paged():
    """90 generated tokens through the DecodeEngine stack (continuous
    batching, ring wrap) under resident and paged plans: identical streams.
    The engine's step-builder path host-shards the cold fetch (lax pipeline,
    see docs/kernels.md) — this guards the full stack around the kernel's
    dispatch seam, kernel-aware prefill-chunk pricing included."""
    cfg = reduced(get_config("mixtral-8x22b"))
    B, S = 2, 96
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    mk = lambda: [Request(0, [5, 9], 90)]  # noqa: E731
    rep_r = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3), mesh, shape,
                         params).run(mk())
    rep_p = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3, n_host=spec.n_cold),
                         mesh, shape, params, paging=spec).run(mk())
    assert rep_r.truncated == () and rep_p.truncated == ()
    assert len(rep_r.finished[0]) == 90
    assert rep_r.finished == rep_p.finished


# ---------------------------------------------------------------------------
# fused int8 quantize+pack vs the three-op sequence (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    z=st.integers(min_value=1, max_value=4),
    n=st.integers(min_value=1, max_value=257),
    me=st.integers(min_value=0, max_value=3),
    seed=st.integers(min_value=0, max_value=2**16),
    log_spread=st.integers(min_value=-3, max_value=4),
)
def test_fused_quantize_matches_three_op_bitwise(z, n, me, seed, log_spread):
    me = me % z
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    ch = (jax.random.normal(ks[0], (z, n), jnp.float32)
          * jnp.exp(jax.random.normal(ks[1], (z, 1)) * log_spread))
    qk, sk, ek = fused_quantize_ef(ch, me, interpret=True)
    qr, sr, er = _fq_ref(ch, me)
    assert qk.dtype == jnp.int8 and sk.dtype == jnp.float32
    assert int(jnp.abs(qk.astype(jnp.int32) - qr.astype(jnp.int32)).max()) == 0
    assert float(jnp.abs(sk - sr).max()) == 0.0
    assert float(jnp.abs(ek - er).max()) == 0.0
    # residual bound: reconstruction error of the owned chunk stays within
    # half a quantization step (scale = absmax/127, no clipping beyond it);
    # slack covers fp32 round-off in ch/scale and ch - q*scale near
    # half-integer quotients (~|q|*eps relative to the step, |q| <= 127)
    bound = float(sk[me]) * 0.5 * (1 + 1e-4) + 1e-30
    assert float(jnp.abs(ek).max()) <= bound


@settings(max_examples=10, deadline=None)
@given(
    me=st.integers(min_value=0, max_value=3),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_fused_quantize_ef_round_trip_matches_three_op(me, seed):
    """Iterated error feedback: feed each iteration's residual back into the
    next chunk (the wire loop of manual_int8_ef_reduce_scatter) on both
    paths; the full (q, scale, err) trajectory must stay bitwise equal."""
    z, n = 4, 64
    err_k = jnp.zeros((n,), jnp.float32)
    err_r = jnp.zeros((n,), jnp.float32)
    for it in range(5):
        ch = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), it),
                               (z, n), jnp.float32) * 3.0
        qk, sk, err_k = fused_quantize_ef(ch.at[me].add(err_k), me, interpret=True)
        qr, sr, err_r = _fq_ref(ch.at[me].add(err_r), me)
        assert int(jnp.abs(qk.astype(jnp.int32) - qr.astype(jnp.int32)).max()) == 0
        assert float(jnp.abs(sk - sr).max()) == 0.0
        assert float(jnp.abs(err_k - err_r).max()) == 0.0
    assert float(jnp.abs(err_k).max()) > 0.0, "EF must accumulate something"


@pytest.mark.skipif(len(jax.devices()) < 2, reason="reduce-scatter needs >1 device")
def test_reduce_scatter_fused_vs_unfused_paths_agree():
    """manual_int8_ef_reduce_scatter under shard_map: the fused-kernel and
    three-op dispatches agree to fp32 fusion noise (inside one jit XLA may
    FMA-fuse the unfused residual subtract — ~1 ulp of the chunk scale; the
    bitwise contract is covered above where both paths jit alone)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.dist.collectives import (
        manual_int8_ef_reduce_scatter,
        set_fused_quant,
    )

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rows = 4 * n_dev
    g = jax.random.normal(jax.random.PRNGKey(0), (n_dev, rows, 6), jnp.float32)
    err0 = jnp.zeros((n_dev, rows // n_dev, 6), jnp.float32)

    def body(gl, el):
        s, ne = manual_int8_ef_reduce_scatter(gl[0], el[0], ("data",), 0)
        return s[None], ne[None]

    def run():
        return jax.jit(shard_map(
            body, mesh,
            in_specs=(P("data", None, None), P("data", None, None)),
            out_specs=(P("data", None, None), P("data", None, None)),
            check=False))(g, err0)

    try:
        set_fused_quant(True)
        s_f, e_f = run()
        set_fused_quant(False)
        s_u, e_u = run()
    finally:
        set_fused_quant(None)
    scale_step = float(jnp.abs(g).max()) / 127.0
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_u),
                               atol=scale_step * 1e-5)
    np.testing.assert_allclose(np.asarray(e_f), np.asarray(e_u),
                               atol=scale_step * 1e-5)


# ---------------------------------------------------------------------------
# dispatch plumbing
# ---------------------------------------------------------------------------
def test_package_dispatch_and_gating():
    """The package-level entry points route through pallas_kernels_active();
    PagedKV auto-gates on it and *always* drops to lax under a host-sharded
    fetch plan (pallas_call is unpartitionable and cannot read host memory
    spaces)."""
    from repro import kernels as K

    assert isinstance(K.pallas_kernels_active(), bool)
    args = _paged_inputs(KEY, 1, 4, 2, 16, 8, 8)
    out = K.decode_paged_attention(*args, n_hot=2)
    ref = _pa_ref(*args)
    assert out.shape == ref.shape
    assert float(jnp.abs(out - ref).max()) == 0.0

    spec = choose_paging(16, 4, 2)
    assert PagedKV(spec).use_kernel == K.pallas_kernels_active()
    assert PagedKV(spec, fetch_sharding=object()).use_kernel is False
    assert PagedKV(spec, use_kernel=False).use_kernel is False
