"""End-to-end behaviour tests for the system as a whole."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, shapes_for
from repro.configs.base import TRAIN_4K, LONG_500K

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_cell_inventory_is_complete():
    """10 archs; decode/prefill everywhere; long_500k only for sub-quadratic."""
    assert len(ARCHS) == 10
    total = 0
    long_archs = []
    for name, cfg in ARCHS.items():
        shapes = {s.name for s in shapes_for(cfg)}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes
        if "long_500k" in shapes:
            long_archs.append(name)
        total += len(shapes)
    assert sorted(long_archs) == [
        "jamba-1.5-large-398b", "mamba2-130m", "mixtral-8x22b",
    ]
    assert total == 33  # 66 dry-run cells over two meshes


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The dry-run harness works end to end (own process: it must own the
    XLA device-count flag before jax initializes)."""
    out = os.path.join(REPO, "reports", "test_dryrun")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "dryrun_cells.jsonl")
    if os.path.exists(path):
        os.remove(path)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "decode_32k", "--mesh", "single", "--out", out],
        env=env, capture_output=True, text=True, timeout=480, cwd=REPO,
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    rec = json.loads(open(path).read().splitlines()[-1])
    assert rec["ok"]
    assert rec["roofline"]["t_memory_s"] > 0
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")


def test_dryrun_sweep_artifacts_fresh_and_green():
    """The committed sweep artifact covers all 66 cells with ok=True."""
    path = os.path.join(REPO, "reports", "dryrun_cells.jsonl")
    if not os.path.exists(path):
        pytest.skip("sweep artifact not present (run repro.launch.dryrun --all)")
    cells = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("ok"):
            cells[(r["arch"], r["shape"], r["mesh"])] = r
    assert len(cells) >= 66, len(cells)
    for (arch, shape, mesh), r in cells.items():
        assert r["roofline"]["flops_per_chip"] > 0, (arch, shape, mesh)


def test_roofline_parser_on_real_compile():
    """Trip-count-aware collective parsing against a known program."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.roofline import parse_collectives

    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None

        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    comp = jax.jit(jax.grad(f)).lower(
        jax.ShapeDtypeStruct((7, 16, 16), jnp.float32),
        jax.ShapeDtypeStruct((4, 16), jnp.float32),
    ).compile()
    ops = parse_collectives(comp.as_text())
    # single device: no collectives, but the parser must not crash and the
    # computation splitter must find the while bodies
    from repro.launch.roofline import _split_computations, _trip_count

    comps = _split_computations(comp.as_text())
    assert any("while" in t for t in comps.values())
    # trip count recovery: some condition computation holds constant(7)
    tcs = [_trip_count(t) for n, t in comps.items() if "compare" in t.lower() or "lt" in t]
    assert any(abs(t - 7.0) < 0.5 for t in tcs), tcs


def test_shape_bytes_parser():
    from repro.launch.roofline import _shape_bytes

    assert _shape_bytes("f32[16384,53248]") == 16384 * 53248 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _shape_bytes("pred[10]") == 10


@pytest.mark.slow
def test_train_cli_end_to_end(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "mamba2-130m",
         "--reduced", "--steps", "6", "--batch", "2", "--seq", "64",
         "--plan", "resident", "--ckpt-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=480, cwd=REPO,
    )
    assert res.returncode == 0, res.stdout[-1500:] + res.stderr[-1500:]
    summary = json.loads(res.stdout.strip().splitlines()[-1])
    assert summary["steps"] == 6
    assert np.isfinite(summary["final_loss"])
