"""core/profiler.py unit tests: transient-op classification and the
liveness-replay watermark (the §3.2 analogue's two load-bearing behaviors
that test_core.py's FLOPs/residual checks did not pin)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiler import _TRANSIENT, profile_fn


def _ops_by_name(profile, name):
    return [op for op in profile.ops if op.name == name]


# ---------------------------------------------------------------------------
# transient-op classification: the paper's intra-operator workspace spike
# ---------------------------------------------------------------------------
def test_sort_classified_transient():
    x = jnp.zeros((128, 64), jnp.float32)
    p = profile_fn(lambda x: jnp.sort(x, axis=-1), x)
    sorts = _ops_by_name(p, "sort")
    assert sorts, f"no sort primitive traced: {[o.name for o in p.ops]}"
    for op in sorts:
        assert op.transient_bytes == op.bytes_out > 0


def test_top_k_classified_transient():
    x = jnp.zeros((64, 512), jnp.float32)
    p = profile_fn(lambda x: jax.lax.top_k(x, 8), x)
    tops = _ops_by_name(p, "top_k")
    assert tops, f"no top_k primitive traced: {[o.name for o in p.ops]}"
    # top_k outputs values + indices; the workspace is priced at the
    # combined output bytes
    assert tops[0].transient_bytes == tops[0].bytes_out > 0


def test_gather_classified_transient():
    x = jnp.zeros((256, 32), jnp.float32)
    idx = jnp.zeros((64,), jnp.int32)
    p = profile_fn(lambda x, i: jnp.take(x, i, axis=0), x, idx)
    gathers = _ops_by_name(p, "gather")
    assert gathers, f"no gather primitive traced: {[o.name for o in p.ops]}"
    assert gathers[0].transient_bytes == gathers[0].bytes_out > 0


def test_concatenate_classified_transient():
    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    p = profile_fn(lambda a, b: jnp.concatenate([a, b], axis=0), a, b)
    cats = _ops_by_name(p, "concatenate")
    assert cats, f"no concatenate primitive traced: {[o.name for o in p.ops]}"
    assert cats[0].transient_bytes == cats[0].bytes_out == 2 * 64 * 64 * 4


def test_elementwise_ops_not_transient():
    x = jnp.zeros((128, 128), jnp.float32)
    p = profile_fn(lambda x: jnp.tanh(x * 2.0) + 1.0, x)
    for op in p.ops:
        assert op.name not in _TRANSIENT
        assert op.transient_bytes == 0


def test_transient_raises_watermark_above_live_set():
    """The sort's workspace counts toward the peak even though its output
    replaces its (dead) input in the live set."""
    n = 256 * 256
    x = jnp.zeros((n,), jnp.float32)
    p_sorted = profile_fn(lambda x: jnp.sort(x).sum(), x)
    p_plain = profile_fn(lambda x: (x * 1.5).sum(), x)
    # same live trajectory (in -> same-size intermediate -> scalar), but the
    # sort adds out_b of workspace on top of the live set
    assert p_sorted.peak_live_bytes >= p_plain.peak_live_bytes + n * 4


# ---------------------------------------------------------------------------
# liveness-replay watermark on a hand-checkable jaxpr
# ---------------------------------------------------------------------------
def test_liveness_peak_frees_dead_intermediates():
    """A chain a->b->c of same-size elementwise ops keeps at most two
    arrays live (producer input + output); the peak must be 2N, not the
    4N a no-free accumulation would report."""
    n = 1 << 16
    nbytes = n * 4

    def chain(x):
        a = x + 1.0
        b = a + 1.0
        c = b + 1.0
        return c

    p = profile_fn(chain, jnp.zeros((n,), jnp.float32))
    assert p.peak_live_bytes == 2 * nbytes


def test_liveness_peak_holds_fanout_live():
    """When an early array is used again at the end, liveness must keep it
    across the middle of the trajectory: x stays live under a and b."""
    n = 1 << 16
    nbytes = n * 4

    def fanout(x):
        a = x * 2.0
        b = a * 2.0
        return b + x  # x's last use is here

    p = profile_fn(fanout, jnp.zeros((n,), jnp.float32))
    # trajectory peaks at {x, a, b} live simultaneously
    assert p.peak_live_bytes == 3 * nbytes


def test_liveness_peak_scalar_reduction_tail():
    """After the reduction, only the scalar output remains live; the peak is
    the two-array plateau, and the final live set is tiny."""
    n = 1 << 16
    nbytes = n * 4

    def f(x):
        y = x * 3.0
        return y.sum()

    p = profile_fn(f, jnp.zeros((n,), jnp.float32))
    assert p.peak_live_bytes == 2 * nbytes
    assert p.ops[-1].live_bytes <= nbytes + 4


def test_watermark_matches_numpy_model():
    """Cross-check the replay against an explicit alloc/free simulation of
    the same chain (allocate output, free vars past last use)."""
    shapes = [(64, 64), (64, 64), (64,)]

    def f(x):
        a = jnp.tanh(x)        # (64, 64)
        b = a * a              # (64, 64), x dead after tanh
        return b.sum(axis=0)   # (64,)

    x = jnp.zeros(shapes[0], jnp.float32)
    p = profile_fn(f, x)
    nb = [int(np.prod(s)) * 4 for s in shapes]
    # replay by hand: {x} -> +a (peak x+a) -> x dies; {a} -> +b (peak a+b)
    # -> a dies after b=a*a; {b} -> +sum
    expected_peak = nb[0] + nb[1]
    assert p.peak_live_bytes == expected_peak
