"""Per-kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_adam import fused_adam
from repro.kernels.rmsnorm import rmsnorm

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention: sweep shapes, GQA ratios, dtypes, masks
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,sq,sk,hd", [
    (1, 4, 4, 128, 128, 64),     # MHA square
    (2, 8, 2, 128, 128, 64),     # GQA 4:1
    (1, 8, 1, 64, 256, 32),      # MQA, cross lengths
    (2, 4, 4, 100, 100, 64),     # non-block-multiple (padding path)
    (1, 16, 8, 256, 256, 128),   # MXU-aligned head dim
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention_matches_ref(b, hq, hkv, sq, sk, hd, causal, window):
    if not causal and sq != sk:
        pytest.skip("cross-attn non-causal covered by square case")
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (b, hq, sq, hd), jnp.float32)
    k = rand(ks[1], (b, hkv, sk, hd), jnp.float32)
    v = rand(ks[2], (b, hkv, sk, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.bfloat16, 2e-2), (jnp.float32, 2e-5)])
def test_flash_attention_dtypes(dtype, atol):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (2, 4, 128, 64), dtype)
    k = rand(ks[1], (2, 4, 128, 64), dtype)
    v = rand(ks[2], (2, 4, 128, 64), dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = R.flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol, rtol=atol
    )


def test_flash_attention_block_shape_independence():
    """Result must not depend on the VMEM tiling."""
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (1, 4, 256, 64), jnp.float32)
    k = rand(ks[1], (1, 4, 256, 64), jnp.float32)
    v = rand(ks[2], (1, 4, 256, 64), jnp.float32)
    o1 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ---------------------------------------------------------------------------
# fused adam
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1000,), (128, 257), (3, 5, 7), (4096,)])
@pytest.mark.parametrize("pdtype", [jnp.bfloat16, jnp.float32])
def test_fused_adam_matches_ref(shape, pdtype):
    ks = jax.random.split(KEY, 5)
    p = rand(ks[0], shape, pdtype)
    g = rand(ks[1], shape, pdtype)
    master = rand(ks[2], shape, jnp.float32)
    m = rand(ks[3], shape, jnp.float32) * 0.1
    v = jnp.abs(rand(ks[4], shape, jnp.float32)) * 0.01
    hp = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, bc1=0.4, bc2=0.3)
    scal = jnp.array([hp["lr"], hp["b1"], hp["b2"], hp["eps"], hp["weight_decay"],
                      hp["bc1"], hp["bc2"], 0.0], jnp.float32)
    got = fused_adam(p, g, master, m, v, scal, interpret=True)
    want = R.fused_adam_ref(p, g, master, m, v, **hp)
    for a, b_ in zip(got, want):
        assert a.shape == b_.shape and a.dtype == b_.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32), atol=1e-5, rtol=1e-5
        )


def test_fused_adam_integrates_with_optimizer():
    from repro.optim.adam import AdamConfig, adam_update, init_opt_state

    params = {"w": rand(KEY, (64, 64), jnp.bfloat16)}
    grads = {"w": rand(jax.random.PRNGKey(1), (64, 64), jnp.bfloat16)}
    s0 = init_opt_state(params)
    ref_p, ref_s, _ = adam_update(params, grads, s0, AdamConfig(), 1e-3)
    s1 = init_opt_state(params)
    fus_p, fus_s, _ = adam_update(
        params, grads, s1, AdamConfig(use_fused_kernel=True), 1e-3
    )
    np.testing.assert_allclose(
        np.asarray(ref_p["w"], np.float32), np.asarray(fus_p["w"], np.float32), atol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(ref_s["m"]["w"]), np.asarray(fus_s["m"]["w"]), atol=1e-5
    )


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 256), (2, 64, 512), (100, 384)])
@pytest.mark.parametrize("dtype,atol", [(jnp.bfloat16, 2e-2), (jnp.float32, 1e-5)])
def test_rmsnorm_matches_ref(shape, dtype, atol):
    x = rand(KEY, shape, dtype)
    scale = rand(jax.random.PRNGKey(1), shape[-1:], dtype) + 1.0
    got = rmsnorm(x, scale, interpret=True)
    want = R.rmsnorm_ref(x, scale)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol, rtol=atol
    )


# ---------------------------------------------------------------------------
# capability-gated package dispatch (repro.kernels behind compat probes)
# ---------------------------------------------------------------------------
def test_package_dispatch_routes_through_capability_check():
    """The public ops come from the package, gated on pallas_supported():
    requesting the fused kernel must work on every backend (interpret mode
    here on CPU) and agree with the reference oracle."""
    from repro import compat
    from repro import kernels as K

    assert isinstance(compat.pallas_supported(), bool)
    if jax.default_backend() == "cpu":
        assert compat.pallas_interpret_required()
    p = rand(KEY, (64, 32), jnp.bfloat16)
    g = rand(jax.random.fold_in(KEY, 1), (64, 32), jnp.bfloat16)
    master = p.astype(jnp.float32)
    m = jnp.zeros_like(master)
    v = jnp.zeros_like(master)
    kw = dict(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
              bc1=0.1, bc2=0.05)
    got = K.fused_adam_update(p, g, master, m, v, **kw)
    want = R.fused_adam_ref(p, g, master, m, v, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)


def test_step_builder_can_request_fused_adam():
    """AdamConfig(use_fused_kernel=True) must lower and run on the CPU test
    backend (interpret mode) — the ROADMAP's capability-check wiring."""
    from repro.optim.adam import AdamConfig, adam_update, init_opt_state

    params = {"w": rand(KEY, (32, 16), jnp.bfloat16)}
    grads = {"w": rand(jax.random.fold_in(KEY, 2), (32, 16), jnp.bfloat16)}
    opt = init_opt_state(params)
    cfg = AdamConfig(lr=1e-2, use_fused_kernel=True)
    new_p, new_opt, gnorm = jax.jit(
        lambda p, g, o: adam_update(p, g, o, cfg, cfg.lr))(params, grads, opt)
    ref_p, ref_opt, _ = adam_update(params, grads, opt,
                                    AdamConfig(lr=1e-2), 1e-2)
    np.testing.assert_allclose(
        np.asarray(new_p["w"], np.float32), np.asarray(ref_p["w"], np.float32),
        atol=2e-2)
    assert float(gnorm) > 0
