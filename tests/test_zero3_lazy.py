"""Manual ZeRO-3 lazy per-chunk gather (ISSUE-4).

The tentpole acceptance criteria beyond the parity tests in
test_manual_sync.py (which parametrize ddp/zero2/zero3):

  * the compiled zero3 program contains s8 all-to-alls (compressed
    reduce-scatter out of the lazy gather's VJP) and **no** full-param-tree
    all-gather outside the per-chunk scan — asserted structurally: no
    stacked-full-shape array (a ZeRO-sharded run leaf at its full logical
    shape, layer axis included) appears anywhere in the HLO, where the zero2
    up-front gather materializes hundreds of them;
  * ``n_buffer`` is meaningful on the manual path: buffered chunks keep
    gathered weights FWD->BWD (stacked-full saves appear), unbuffered ones
    re-gather in BWD (they don't);
  * ``estimate_memory`` for a zero3 plan no longer charges the
    gathered-all-params or full-local-grad workspace terms (regression vs
    the zero2 estimate);
  * checkpoint round-trip of the manual ZeRO state — shard-sized EF
    residuals included — restores bit-identically and keeps training
    (satellite: ckpt/checkpoint.py coverage);
  * the calibration JSON schema is versioned with explicit defaulting: an
    old-format file (no version, no gather factor) loads without KeyError.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import cost_model as CM
from repro.core.plan import MemoryPlan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist.sharding import leaf_sync_dim, zero_axes
from repro.train.step_builder import build_train_step

N_DEV = len(jax.devices())
TINY = reduced(ARCHS["llama3-405b"])
SHAPE = ShapeConfig("tiny", 32, 16, "train")
# deeper variant for the analytic regressions: enough chunks that the
# full-grad-tree workspace term visibly exceeds the largest-chunk term
DEEP = dataclasses.replace(reduced(ARCHS["llama3-405b"]), num_layers=8,
                           d_model=256, d_ff=1024, vocab_size=1024)

needs_multi_device = pytest.mark.skipif(
    N_DEV < 2 or 16 % N_DEV != 0,
    reason="zero3 lazy gather needs a multi-device mesh (CI forces 4)",
)


def dp_mesh(n=None):
    n = n if n is not None else N_DEV
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def zero_plan(n_persist=0, **kw):
    kw.setdefault("grad_compress", "int8_ef")
    kw.setdefault("sync_mode", "manual")
    return MemoryPlan(n_chunks=4, n_blocks=2, n_persist=n_persist, **kw)


def _stacked_full_shapes(art, mesh) -> set[str]:
    """HLO shape strings of every ZeRO-sharded run leaf at its *stacked full*
    size — what an up-front (non-per-chunk) gather would materialize."""
    out = set()
    for run in art.state_specs["params"]["runs"]:
        for leaf in jax.tree.leaves(
                run, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
            if (leaf_sync_dim(leaf.sharding, zero_axes(mesh)) is not None
                    and leaf.shape[0] > 1):
                dt = {"bfloat16": "bf16", "float32": "f32"}[str(leaf.dtype)]
                out.add(f"{dt}[{','.join(map(str, leaf.shape))}]")
    return out


# ---------------------------------------------------------------------------
# compiled-program structure
# ---------------------------------------------------------------------------
@needs_multi_device
def test_zero3_hlo_s8_scatter_and_no_full_tree_gather():
    """Acceptance: s8 all-to-alls present, and no ZeRO-sharded run leaf ever
    exists at stacked-full shape — the gathers live inside the per-chunk
    scan, full params never coexist."""
    mesh = dp_mesh()
    art = build_train_step(TINY, zero_plan(zero_stage=3), mesh, SHAPE)
    hlo = art.lower(donate=False).compile().as_text()
    s8_a2a = [ln for ln in hlo.splitlines()
              if "all-to-all" in ln and "s8[" in ln]
    assert s8_a2a, "expected s8 all-to-alls (compressed reduce-scatter VJP)"
    shapes = _stacked_full_shapes(art, mesh)
    assert shapes, "tiny model should have ZeRO-sharded stacked run leaves"
    leaked = {s: hlo.count(s) for s in shapes if s in hlo}
    assert not leaked, (
        f"full-param-tree material outside the per-chunk scan: {leaked}")


@needs_multi_device
def test_zero3_n_buffer_controls_fwd_to_bwd_weight_buffering():
    """n_buffer is meaningful on the manual path: a fully-buffered zero3
    plan saves gathered weights FWD->BWD, an unbuffered one re-gathers in
    BWD (no stacked-full arrays anywhere). Since the prefetch pipeline
    (models/model._apply_run_prefetched) the buffered run carries gathered
    weights through the scan — chunk k+1's gather is issued during chunk
    k's compute — so the saves appear stacked at ``n_repeats - 1`` leading
    (the scanned iterations; the pre-gathered first and trailing last
    repeat are saved unstacked). A 4-block model keeps the scan rolled
    (length 3), which is what makes the stacking visible in HLO."""
    mesh = dp_mesh()
    cfg4 = dataclasses.replace(TINY, num_layers=4)

    def plan4(**kw):
        kw.setdefault("grad_compress", "int8_ef")
        kw.setdefault("sync_mode", "manual")
        return MemoryPlan(n_chunks=6, n_blocks=4, **kw)

    art_buf = build_train_step(cfg4, plan4(n_buffer=6, zero_stage=3), mesh, SHAPE)
    hlo_buf = art_buf.lower(donate=False).compile().as_text()
    full = _stacked_full_shapes(art_buf, mesh)  # leading dim == n_repeats
    carried = {s.split("[")[0] + "[3," + s.split(",", 1)[1] for s in full}
    assert any(s in hlo_buf for s in carried), (
        "buffered zero3 should keep gathered weights live FWD->BWD "
        "(scan-carried stacks from the prefetch pipeline)")

    art_un = build_train_step(cfg4, plan4(zero_stage=3), mesh, SHAPE)
    hlo_un = art_un.lower(donate=False).compile().as_text()
    assert not any(s in hlo_un for s in full | carried), (
        "unbuffered zero3 must re-gather in BWD, never stack saved weights")


@needs_multi_device
def test_zero3_mixed_persist_microbatch_and_bf16_train():
    """Mixed persist/ZeRO chunks, gradient accumulation, and the bf16 wire
    format (residual-less VJP: err=None threads through gather_param_lazy)
    all lower and train finitely under the lazy path."""
    mesh = dp_mesh()
    for plan in (zero_plan(n_persist=2, zero_stage=3),
                 zero_plan(microbatch=2, zero_stage=3),
                 zero_plan(grad_compress="bf16", microbatch=2, zero_stage=3)):
        art = build_train_step(TINY, plan, mesh, SHAPE)
        state = art.init(jax.random.PRNGKey(0))
        jfn = jax.jit(art.fn, donate_argnums=(0,))
        pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
        for _ in range(2):
            state, metrics = jfn(state, pipe.next_sync())
        assert np.isfinite(float(metrics["loss"]))
        if plan.grad_compress == "int8_ef":
            assert float(metrics["ef_norm"]) > 0


# ---------------------------------------------------------------------------
# checkpoint round-trip of the manual ZeRO state (EF + shard-resident fp32)
# ---------------------------------------------------------------------------
@needs_multi_device
def test_ckpt_roundtrip_manual_zero3_state(tmp_path):
    """The full manual-zero3 train state — bf16 param shards, shard-resident
    fp32 optimizer state, shard-sized EF residuals, and a buffered plan's
    layout — survives a save/restore round trip bit-identically and
    continues training to the same loss."""
    from repro.ckpt.checkpoint import CheckpointManager

    mesh = dp_mesh()
    plan = zero_plan(n_buffer=2, zero_stage=3)
    art = build_train_step(TINY, plan, mesh, SHAPE)
    state = art.init(jax.random.PRNGKey(0))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    state, _ = jfn(state, pipe.next_sync())

    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, state, extra={"plan": plan.describe()}, sync=True)
    restored, extra = mgr.restore(1, art.state_specs)
    assert extra["plan"] == plan.describe()

    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # EF leaves restored with their sharded layout intact (shard-sized on
    # each device, full logical shape globally)
    axes = zero_axes(mesh)
    sharded = 0
    for e in jax.tree.leaves(restored["ef"]):
        d = leaf_sync_dim(e.sharding, axes)
        if d is not None:
            sharded += 1
            assert e.addressable_shards[0].data.shape[d] == e.shape[d] // N_DEV
    assert sharded > 0

    batch = pipe.next_sync()
    _, m1 = jfn(jax.tree.map(lambda x: x.copy(), state), batch)
    _, m2 = jfn(restored, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# cost model regressions
# ---------------------------------------------------------------------------
def _deep_workload():
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    return build_workload(DEEP, ShapeConfig("fid", 32, 16, "train"),
                          MeshSpec((4,), ("data",)), TPU_V5E)


def test_zero3_memory_estimate_drops_gathered_and_grad_workspace():
    """Acceptance: estimate_memory(zero3) no longer charges the
    gathered-all-params term (only buffered chunks + the two in-flight
    units) or the full-local-grad workspace (only the largest chunk's
    transient cotangent)."""
    w = _deep_workload()
    nc, nb = w.n_chunks, w.n_blocks
    z2 = MemoryPlan(nc, nb, grad_compress="int8_ef", sync_mode="manual",
                    zero_stage=2)
    z3 = MemoryPlan(nc, nb, grad_compress="int8_ef", sync_mode="manual",
                    zero_stage=3)
    m2, m3 = CM.estimate_memory(w, z2), CM.estimate_memory(w, z3)
    assert m3.gathered_buffers < m2.gathered_buffers
    assert m3.workspace < m2.workspace
    assert m3.peak < m2.peak
    # buffering brings the gathered charge back chunk by chunk
    z3_buf = dataclasses.replace(z3, n_buffer=nc)
    m3b = CM.estimate_memory(w, z3_buf)
    assert m3.gathered_buffers < m3b.gathered_buffers <= m2.gathered_buffers


def test_zero3_runtime_prices_regather_and_zero2_does_not():
    """zero2 never re-gathers (up-front gather kept for the step); an
    unbuffered zero3 plan pays BWD re-gathers, and buffering removes them."""
    w = _deep_workload()
    nc, nb = w.n_chunks, w.n_blocks
    mk = lambda **kw: MemoryPlan(nc, nb, grad_compress="int8_ef",  # noqa: E731
                                 sync_mode="manual", **kw)
    t2 = CM.estimate_runtime(w, mk(zero_stage=2)).t_iteration
    t3 = CM.estimate_runtime(w, mk(zero_stage=3)).t_iteration
    t3b = CM.estimate_runtime(w, mk(zero_stage=3, n_buffer=nc)).t_iteration
    assert t3b <= t3
    assert t2 <= t3


def test_t_gather_uses_calibrated_gather_factor(tmp_path):
    """The manual param gathers are priced by the fitted gather_bf16 factor;
    the xla path's GSPMD gathers are untouched by it."""
    w = _deep_workload()
    chunk = w.chunks[1]
    xla_plan = MemoryPlan(w.n_chunks, w.n_blocks)
    man_plan = MemoryPlan(w.n_chunks, w.n_blocks, grad_compress="int8_ef",
                          sync_mode="manual", zero_stage=3)
    path = tmp_path / "cal.json"
    try:
        vals = {}
        for factor in (1.0, 0.5):
            path.write_text(json.dumps({"version": 2, "backends": {
                jax.default_backend(): {"wire_factors": {
                    "xla": {"none": 1.0},
                    "manual": {"none": 1.0, "int8_ef": 0.5,
                               "int8_ef_rs": 0.5, "gather_bf16": factor},
                }}}}))
            CM.load_wire_calibration(str(path))
            vals[factor] = (w.t_gather(chunk, man_plan),
                            w.t_gather(chunk, xla_plan), w.t_gather(chunk))
        np.testing.assert_allclose(vals[0.5][0], vals[1.0][0] * 0.5)
        assert vals[0.5][1] == vals[1.0][1]  # xla plan: factor not applied
        assert vals[0.5][2] == vals[1.0][2]  # plan-less call: legacy behavior
    finally:
        CM.reset_wire_calibration()


def test_old_calibration_schema_loads_with_defaults(tmp_path):
    """Satellite: forward-compat guard — a pre-version JSON (no "version"
    key, no gather_bf16/int8_ef_rs factors, no ef_residual_factor) loads
    without KeyError and every missing key resolves to the analytic
    default."""
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"backends": {jax.default_backend(): {
        "wire_factors": {"xla": {"none": 1.0, "bf16": 1.0, "int8_ef": 1.0},
                         "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5}},
    }}}))
    try:
        entry = CM.load_wire_calibration(str(path))
        assert entry is not None
        assert CM.wire_factor("manual", "int8_ef") == 0.5  # present: used
        assert CM.wire_factor("manual", "gather_bf16") == \
            CM.DEFAULT_WIRE_FACTORS["manual"]["gather_bf16"]
        assert CM.wire_factor("manual", "int8_ef_rs") == \
            CM.DEFAULT_WIRE_FACTORS["manual"]["int8_ef_rs"]
        assert CM.ef_residual_factor() == CM.DEFAULT_EF_RESIDUAL_FACTOR
    finally:
        CM.reset_wire_calibration()


# ---------------------------------------------------------------------------
# autotuner integration
# ---------------------------------------------------------------------------
def test_autotuner_zero3_candidates_search_n_buffer():
    """Manual cells emit both ZeRO dataflows; under a capacity that rules out
    the replicated and zero2 layouts the winner is a zero3 plan, with
    n_buffer maximized under what fits."""
    from repro.core import search

    w = _deep_workload()
    nc, nb = w.n_chunks, w.n_blocks
    lo = CM.estimate_memory(w, MemoryPlan(
        nc, nb, grad_compress="int8_ef", sync_mode="manual", zero_stage=3)).peak
    hi = CM.estimate_memory(w, MemoryPlan(
        nc, nb, grad_compress="int8_ef", sync_mode="manual", zero_stage=2)).peak
    assert lo < hi
    res = search(w, capacity_bytes=(lo + hi) / 2, compress="on", sync="manual",
                 allow_host=False, allow_swap=False)
    assert res.feasible
    assert res.plan.manual_sync_kind(w.mesh.tp_degree) == "zero3"
    assert res.memory.peak < (lo + hi) / 2
