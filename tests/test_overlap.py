"""Overlap-aware manual sync (ISSUE-7).

Tentpole acceptance beyond the parity suites in test_manual_sync.py /
test_zero3_lazy.py:

  * loss parity within bf16 tolerance over 10 steps for the overlapped
    manual schedules vs their inline (``overlap=False``) twins, across
    zero2 / zero3 / prefetched-buffered zero3 — the overlap machinery
    (double-buffered gather prefetch, deferred-accumulation reduce-scatter)
    reorders collectives but must not change what is computed;
  * the prefetch pipeline is visible in the lowered program: chunk k+1's
    all-gather output is ``optimization_barrier``-paired with the incoming
    activation (chunk k-1's output), the same double-buffer idiom as
    serve/paging — and the s8 payloads still survive on the wire;
  * the cost model's overlap term: ``overlap=True`` prices each chunk at
    max(compute, comm), ``overlap=False`` serializes (sum), so the
    overlapped estimate is *strictly* below the serial baseline whenever a
    chunk has both compute and comm — the BENCH_train.json acceptance;
  * ``gather_prefetch_depth`` encodes the serial fallback: depth 2 only
    for overlapped manual zero3 with ``n_buffer >= 2``, else 1;
  * property suite: ``zero3_prefetch_schedule`` never holds more than
    ``max(n_buffer, 1)`` gather buffers live and never exceeds the two
    in-flight gather units ``estimate_memory`` charges, for arbitrary
    ``(n_chunks, n_buffer, microbatch)``.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import cost_model as CM
from repro.core.plan import MemoryPlan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.optim.adam import AdamConfig
from repro.train.step_builder import build_train_step

N_DEV = len(jax.devices())
TINY = reduced(ARCHS["llama3-405b"])
SHAPE = ShapeConfig("tiny", 32, 16, "train")
DEEP = dataclasses.replace(reduced(ARCHS["llama3-405b"]), num_layers=8,
                           d_model=256, d_ff=1024, vocab_size=1024)

needs_multi_device = pytest.mark.skipif(
    N_DEV < 2 or 16 % N_DEV != 0,
    reason="overlap parity needs a multi-device mesh (CI forces 4)",
)


def dp_mesh(n=None):
    n = n if n is not None else N_DEV
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def zero_plan(**kw):
    kw.setdefault("grad_compress", "int8_ef")
    kw.setdefault("sync_mode", "manual")
    return MemoryPlan(n_chunks=4, n_blocks=2, **kw)


def run_steps(plan, mesh, steps=10, lr=3e-3, seed=0):
    art = build_train_step(TINY, plan, mesh, SHAPE, adam=AdamConfig(lr=lr))
    state = art.init(jax.random.PRNGKey(seed))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    losses, metrics = [], None
    for _ in range(steps):
        state, metrics = jfn(state, pipe.next_sync())
        losses.append(float(metrics["loss"]))
    return art, state, losses, metrics


def _deep_workload():
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    return build_workload(DEEP, ShapeConfig("fid", 32, 16, "train"),
                          MeshSpec((4,), ("data",)), TPU_V5E)


# ---------------------------------------------------------------------------
# numerics parity: overlapped vs inline schedules
# ---------------------------------------------------------------------------
@needs_multi_device
@pytest.mark.parametrize("plan", [
    zero_plan(zero_stage=2, microbatch=2),
    zero_plan(zero_stage=3, microbatch=2),
    zero_plan(zero_stage=3, n_buffer=4),
], ids=["zero2", "zero3", "zero3_buffered"])
def test_overlap_parity_prefetched_vs_inline(plan):
    """Acceptance: the overlapped program (gather prefetch for the buffered
    cell, deferred-accumulation reduce-scatter for the microbatched cells)
    tracks the inline ``overlap=False`` twin within bf16 tolerance over 10
    steps. The deferred accumulation performs the serial path's exact fp32
    adds one iteration later, and the prefetch pipeline issues the same
    gathers earlier — only op *ordering* changes, so bf16 rounding drift
    from re-fused matmuls is the only tolerated difference."""
    mesh = dp_mesh()
    assert plan.overlap  # overlap is the default
    _, _, l_ov, m_ov = run_steps(plan, mesh)
    _, _, l_ser, _ = run_steps(dataclasses.replace(plan, overlap=False), mesh)
    assert all(np.isfinite(l_ov))
    np.testing.assert_allclose(l_ov, l_ser, rtol=2e-2)
    assert float(m_ov["ef_norm"]) > 0


# ---------------------------------------------------------------------------
# compiled-program structure: barrier-ordered prefetch, s8 on the wire
# ---------------------------------------------------------------------------
def _act_tensor() -> str:
    """StableHLO type of the per-device scan activation — the prefetch
    anchor's second barrier operand."""
    return (f"tensor<{SHAPE.global_batch // N_DEV}x{SHAPE.seq_len}"
            f"x{TINY.d_model}xbf16>")


def _prefetch_anchor_lines(txt: str) -> list[str]:
    """Barrier ops of the prefetch-anchor shape: exactly two operands,
    (gathered weight, activation). Remat also lowers to optimization_barrier
    but bundles dozens of residuals — operand arity tells them apart."""
    act = _act_tensor()
    out = []
    for ln in txt.splitlines():
        if "optimization_barrier" not in ln or ":" not in ln:
            continue
        types = ln.rsplit(":", 1)[1].split(",")
        if len(types) == 2 and act in types[1]:
            out.append(ln.strip())
    return out



@needs_multi_device
def test_prefetch_pipeline_barrier_orders_gathers_in_hlo():
    """The buffered zero3 program issues chunk k+1's all-gather inside the
    scan body barrier-paired with the incoming activation (chunk k-1's
    output) — the serve/paging double-buffer idiom — so the gather cannot
    sink to its point of use. XLA consumes the barriers during scheduling,
    so the witness lives in the *lowered* text: optimization_barrier ops
    pairing a full gathered-weight tensor with the activation tensor. The
    serial twin (overlap=False) must emit none, and the compiled overlapped
    program must still move s8 payloads (compression survives the
    pipeline)."""
    mesh = dp_mesh()
    plan = zero_plan(zero_stage=3, n_buffer=4)
    art = build_train_step(TINY, plan, mesh, SHAPE)
    lowered = art.lower(donate=False)
    txt = lowered.as_text()
    # the anchor's operands are (gathered weights, activation): the weight
    # paired with the rank-3 activation that orders the gather after chunk
    # k-1's output
    paired = _prefetch_anchor_lines(txt)
    assert paired, "no barrier pairs a gather with the scan activation"

    hlo = lowered.compile().as_text()
    s8_a2a = [ln for ln in hlo.splitlines() if "all-to-all" in ln and "s8[" in ln]
    assert s8_a2a, "s8 reduce-scatter payloads must survive the prefetch"

    art_ser = build_train_step(
        TINY, dataclasses.replace(plan, overlap=False), mesh, SHAPE)
    txt_ser = art_ser.lower(donate=False).as_text()
    assert "optimization_barrier" not in txt_ser, (
        "overlap=False is the serial fallback: no prefetch anchors")


@needs_multi_device
def test_serial_fallback_below_double_buffer_floor():
    """n_buffer < 2 cannot double-buffer (nothing to prefetch into), so the
    plan reports depth 1 and the lowered program gathers inline — no
    barrier ever pairs a gather with the scan activation. That is the
    documented serial fallback."""
    mesh = dp_mesh()
    plan = zero_plan(zero_stage=3, n_buffer=1)
    assert plan.gather_prefetch_depth == 1
    art = build_train_step(TINY, plan, mesh, SHAPE)
    txt = art.lower(donate=False).as_text()
    assert not _prefetch_anchor_lines(txt), (
        "below the floor there must be no prefetch anchors")


# ---------------------------------------------------------------------------
# cost model: the overlap term
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mk", [
    lambda nc, nb: MemoryPlan(nc, nb, grad_compress="int8_ef",
                              sync_mode="manual", zero_stage=2),
    lambda nc, nb: MemoryPlan(nc, nb, grad_compress="int8_ef",
                              sync_mode="manual", zero_stage=3),
    lambda nc, nb: MemoryPlan(nc, nb, n_buffer=nc, grad_compress="int8_ef",
                              sync_mode="manual", zero_stage=3),
], ids=["zero2", "zero3", "zero3_buffered"])
def test_overlap_pricing_strictly_beats_serial(mk):
    """Acceptance (BENCH_train.json): t_overlap = max(compute, comm) per
    chunk is *strictly* below the serial sum whenever any chunk has both
    compute and comm — true for every manual plan on a real workload."""
    w = _deep_workload()
    plan = mk(w.n_chunks, w.n_blocks)
    t_ov = CM.estimate_runtime(w, plan)
    t_ser = CM.estimate_runtime(w, dataclasses.replace(plan, overlap=False))
    assert t_ov.t_fwd < t_ser.t_fwd
    assert t_ov.t_bwd < t_ser.t_bwd
    assert t_ov.t_iteration < t_ser.t_iteration


def test_overlap_flag_is_inert_on_the_xla_path():
    """GSPMD owns overlap on the xla path; the knob prices nothing there."""
    w = _deep_workload()
    plan = MemoryPlan(w.n_chunks, w.n_blocks, grad_compress="int8_ef")
    t_on = CM.estimate_runtime(w, plan)
    t_off = CM.estimate_runtime(w, dataclasses.replace(plan, overlap=False))
    assert t_on.t_iteration == t_off.t_iteration


def test_autotuner_threads_overlap_into_candidates():
    """search(overlap=...) stamps the flag on the winning plan, and scoring
    with the serial schedule can only slow the projected step down."""
    from repro.core import search

    w = _deep_workload()
    res_ov = search(w, compress="on", sync="manual",
                    allow_host=False, allow_swap=False)
    res_ser = search(w, compress="on", sync="manual",
                     allow_host=False, allow_swap=False, overlap=False)
    assert res_ov.feasible and res_ser.feasible
    assert res_ov.plan.overlap and not res_ser.plan.overlap
    assert res_ov.runtime.t_iteration <= res_ser.runtime.t_iteration


DEPTH_LATTICE = [
    # (sync_mode, zero_stage, n_buffer, overlap) -> depth
    (("manual", 3, 4, True), 2),
    (("manual", 3, 2, True), 2),
    (("manual", 3, 1, True), 1),   # serial fallback: below the floor
    (("manual", 3, 0, True), 1),
    (("manual", 3, 4, False), 1),  # overlap off: always inline
    (("manual", 2, 4, True), 1),   # zero2 gathers up front, nothing to pipe
    (("xla", 3, 4, True), 1),      # GSPMD owns xla-path prefetch
]


@pytest.mark.parametrize("cell,depth", DEPTH_LATTICE)
def test_gather_prefetch_depth_lattice(cell, depth):
    sync_mode, zero_stage, n_buffer, overlap = cell
    plan = MemoryPlan(4, 2, n_buffer=n_buffer, sync_mode=sync_mode,
                      zero_stage=zero_stage, overlap=overlap,
                      grad_compress="int8_ef" if sync_mode == "manual" else "none")
    assert plan.gather_prefetch_depth == depth


# ---------------------------------------------------------------------------
# property suite: the prefetch schedule's buffer discipline
# ---------------------------------------------------------------------------
@given(nb=st.integers(1, 10), nbuf=st.integers(0, 12),
       microbatch=st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_prefetch_schedule_never_exceeds_buffer_budget(nb, nbuf, microbatch):
    """For arbitrary (n_chunks, n_buffer, microbatch) the overlap schedule
    never holds more than max(n_buffer, 1) gather buffers live and never
    has more gathers in flight than the double-buffer depth allows — the
    same budget estimate_memory charges (n_buffer buffered chunks plus two
    in-flight gather units)."""
    nc = nb + 2  # embed + blocks + head, the MemoryPlan invariant
    nbuf = min(nbuf, nc)
    sched = CM.zero3_prefetch_schedule(nc, nbuf, microbatch=microbatch)
    assert sched["max_live"] <= max(nbuf, 1)
    depth = 2 if nbuf >= 2 else 1
    assert sched["max_inflight"] <= depth - 1
    # estimate_memory's in-flight charge (2 gather units) covers the
    # schedule: one executing + at most depth-1 prefetched
    assert sched["max_inflight"] + 1 <= 2

    # the schedule's buffered set is exactly the plan's chunk_buffered set
    plan = MemoryPlan(nc, nb, n_buffer=nbuf, grad_compress="int8_ef",
                      sync_mode="manual", zero_stage=3)
    assert {i for i in range(nc) if plan.chunk_buffered(i)} == \
        {i for i in range(nc) if i >= nc - nbuf}
    assert plan.gather_prefetch_depth == depth


@given(nbuf=st.integers(2, 8))
@settings(max_examples=8, deadline=None)
def test_prefetch_schedule_uses_the_pipeline(nbuf):
    """With a double-bufferable window the schedule actually prefetches:
    at least one gather is in flight ahead of compute."""
    nc = nbuf + 2
    sched = CM.zero3_prefetch_schedule(nc, nbuf)
    assert sched["max_inflight"] == 1
    # forcing the serial depth drains the pipeline
    assert CM.zero3_prefetch_schedule(nc, nbuf, prefetch_depth=1)[
        "max_inflight"] == 0


# ---------------------------------------------------------------------------
# calibration: the informational overlap record, and legacy-JSON loading
# ---------------------------------------------------------------------------
def test_calibration_overlap_record_and_legacy_load(tmp_path):
    """The regenerated packaged calibration carries the informational
    ``overlap`` record (modeled hidden-comm fraction inside calibrate_wire's
    dry-run band), and a pre-ISSUE-7 calibration *without* the key loads and
    prices identically — nothing in cost_model reads it, so per-key
    defaulting (schema v2) is undisturbed."""
    import json
    import os

    packaged = os.path.join(os.path.dirname(CM.__file__),
                            "wire_calibration.json")
    with open(packaged) as f:
        doc = json.load(f)
    assert doc["version"] == CM.CALIBRATION_SCHEMA_VERSION == 2
    entry = next(iter(doc["backends"].values()))
    frac = entry["overlap"]["hidden_comm_fraction"]
    assert 0.02 <= frac <= 0.95

    legacy = {"version": 2, "backends": {
        b: {k: v for k, v in e.items() if k != "overlap"}
        for b, e in doc["backends"].items()}}
    p = tmp_path / "legacy_no_overlap.json"
    p.write_text(json.dumps(legacy))
    try:
        loaded = CM.load_wire_calibration(str(p))
        assert loaded is not None and "overlap" not in loaded
        assert CM.wire_factor("manual", "int8_ef_rs") == pytest.approx(
            entry["wire_factors"]["manual"]["int8_ef_rs"])
    finally:
        CM.reset_wire_calibration()
