"""Manual shard_map gradient sync (MemoryPlan.sync_mode="manual").

Covers the ISSUE-2 acceptance criteria: numerics parity with the xla path on
a multi-device mesh (CI forces 4 CPU devices), error-feedback residuals that
carry across steps, the 1-device fallback guard, structural eligibility
errors, the wire-cost calibration round trip, and the autotuner searching
sync_mode with calibrated factors."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import cost_model as CM
from repro.core.plan import MemoryPlan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.optim.adam import AdamConfig
from repro.train.step_builder import build_train_step

N_DEV = len(jax.devices())
TINY = reduced(ARCHS["llama3-405b"])
SHAPE = ShapeConfig("tiny", 32, 16, "train")  # local batch 16/N_DEV per device

needs_multi_device = pytest.mark.skipif(
    N_DEV < 2 or 16 % N_DEV != 0,
    reason="manual-vs-xla parity needs a multi-device mesh (CI forces 4)",
)


def dp_mesh(n=None):
    n = n if n is not None else N_DEV
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def run_steps(plan, mesh, steps=10, lr=3e-3, seed=0):
    art = build_train_step(TINY, plan, mesh, SHAPE, adam=AdamConfig(lr=lr))
    state = art.init(jax.random.PRNGKey(seed))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    losses, metrics = [], None
    for _ in range(steps):
        state, metrics = jfn(state, pipe.next_sync())
        losses.append(float(metrics["loss"]))
    return art, state, losses, metrics


def persist_plan(**kw):
    return MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4, **kw)


# ---------------------------------------------------------------------------
# numerics parity + EF carry-over
# ---------------------------------------------------------------------------
@needs_multi_device
def test_manual_matches_xla_losses_over_ten_steps():
    """Acceptance: int8+EF manual sync tracks the xla path within bf16
    tolerance over >= 10 steps (the paths quantize before vs after the
    reduce, so they are not bitwise equal — EF keeps them together)."""
    mesh = dp_mesh()
    _, _, l_xla, _ = run_steps(
        persist_plan(grad_compress="int8_ef", sync_mode="xla"), mesh)
    _, _, l_man, m_man = run_steps(
        persist_plan(grad_compress="int8_ef", sync_mode="manual"), mesh)
    assert all(np.isfinite(l_man))
    # bf16 has ~8 mantissa bits: tolerate ~2 ulp of relative drift
    np.testing.assert_allclose(l_man, l_xla, rtol=2e-2)
    assert float(m_man["ef_norm"]) > 0


@needs_multi_device
def test_manual_int8_payload_is_on_the_wire():
    """The compiled manual program must move s8 payloads (real compression),
    and must contain no fp32 gradient all-reduce."""
    mesh = dp_mesh()
    art = build_train_step(
        TINY, persist_plan(grad_compress="int8_ef", sync_mode="manual"), mesh, SHAPE)
    hlo = art.lower(donate=False).compile().as_text()
    s8_gathers = [ln for ln in hlo.splitlines() if "all-gather(" in ln and "s8[" in ln]
    assert s8_gathers, "expected int8 all-gathers in the manual-sync HLO"


@needs_multi_device
def test_manual_ef_residual_carries_across_steps():
    mesh = dp_mesh()
    plan = persist_plan(grad_compress="int8_ef", sync_mode="manual")
    art, state, _, _ = run_steps(plan, mesh, steps=1)
    # manual EF is device-varying state, stored stacked (n_sync leading axis,
    # sharded over the sync axes) so checkpoints see every device's residual
    for leaf in jax.tree.leaves(state["ef"]):
        assert leaf.shape[0] == N_DEV
    ef1 = [np.asarray(x) for x in jax.tree.leaves(state["ef"])]
    assert any(np.abs(e).max() > 0 for e in ef1)  # quantization dropped something
    # the per-device slices genuinely differ (each fed back its own error)
    assert any(
        np.abs(e[0] - e[1]).max() > 0 for e in ef1 if e.shape[0] > 1
    )

    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=1)
    state2, _ = jfn(state, pipe.next_sync())
    ef2 = [np.asarray(x) for x in jax.tree.leaves(state2["ef"])]
    # the residual is live state: it keeps changing as new error feeds back
    assert any(np.abs(a - b).max() > 0 for a, b in zip(ef1, ef2))


@needs_multi_device
def test_manual_microbatch_sync_per_microbatch():
    mesh = dp_mesh()
    plan = persist_plan(grad_compress="int8_ef", sync_mode="manual",
                        microbatch=2)
    _, state, losses, metrics = run_steps(plan, mesh, steps=3)
    assert all(np.isfinite(losses))
    assert float(metrics["ef_norm"]) > 0


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------
def test_manual_one_device_mesh_falls_back_to_local_math():
    """Same guard policy as the mesh-size checks in dist/collectives.py: a
    1-device mesh takes the local math path (wire numerics, no collectives)."""
    mesh = dp_mesh(1)
    plan = persist_plan(grad_compress="int8_ef", sync_mode="manual")
    _, _, losses, metrics = run_steps(plan, mesh, steps=2)
    assert all(np.isfinite(losses))
    assert float(metrics["ef_norm"]) > 0


def test_manual_rejects_non_replicated_layouts():
    # eligibility is validated on every mesh size — including 1 device, so
    # locally-exercised code fails the same way it would deployed
    for n in {1, N_DEV}:
        with pytest.raises(ValueError, match="manual"):
            build_train_step(
                TINY, MemoryPlan(n_chunks=4, n_blocks=2, grad_compress="int8_ef",
                                 sync_mode="manual"),
                dp_mesh(n), SHAPE)


def test_search_rejects_manual_sync_without_compression():
    from repro.core import TPU_V5E, build_workload, search
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4,), ("data",)), TPU_V5E)
    with pytest.raises(ValueError, match="manual"):
        search(w, compress="off", sync="manual")


def test_manual_sync_ok_predicate():
    ok = persist_plan(grad_compress="int8_ef", sync_mode="manual")
    assert ok.manual_sync_ok(tp_degree=1)
    assert not ok.manual_sync_ok(tp_degree=4)  # TP shards the params
    assert persist_plan(dp_only=True).manual_sync_ok(tp_degree=4)
    assert not MemoryPlan(4, 2).manual_sync_ok(1)  # ZeRO-sharded
    assert not MemoryPlan(4, 2, n_persist=4, n_swap=1).manual_sync_ok(1)


# ---------------------------------------------------------------------------
# wire-cost calibration: fit -> JSON -> cost model
# ---------------------------------------------------------------------------
def test_calibration_roundtrip(tmp_path):
    path = tmp_path / "wire_calibration.json"
    doc = {
        "generated_by": "test",
        "backends": {
            jax.default_backend(): {
                "wire_factors": {
                    "xla": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.9},
                    "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.3},
                },
                "ef_residual_factor": 2.5,
            }
        },
    }
    path.write_text(json.dumps(doc))
    try:
        entry = CM.load_wire_calibration(str(path))
        assert entry is not None
        assert CM.wire_factor("xla", "int8_ef") == 0.9
        assert CM.wire_factor("manual", "int8_ef") == 0.3
        assert CM.ef_residual_factor() == 2.5
    finally:
        CM.reset_wire_calibration()


def test_packaged_calibration_overrides_hardcoded_constant():
    """Acceptance: the autotuner's wire costs come from the calibration JSON,
    not the legacy GRAD_WIRE_FACTOR constant — the measured xla-path factor is
    1.0 (in-jit compression never touched the wire), where the constant
    claims 0.5."""
    CM.reset_wire_calibration()
    entry = CM.load_wire_calibration()
    assert entry is not None, "packaged src/repro/core/wire_calibration.json missing"
    assert CM.wire_factor("xla", "int8_ef") == 1.0
    assert CM.wire_factor("xla", "int8_ef") != CM.GRAD_WIRE_FACTOR["int8_ef"]
    assert CM.wire_factor("manual", "int8_ef") < 1.0  # real compression


def test_t_reduce_uses_calibrated_factor(tmp_path):
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4, 1), ("data", "model")), TPU_V5E)
    chunk = w.chunks[1]
    base = persist_plan(grad_compress="int8_ef", sync_mode="xla")

    path = tmp_path / "cal.json"
    for factor in (1.0, 0.5):
        doc = {"backends": {jax.default_backend(): {
            "wire_factors": {"xla": {"none": 1.0, "bf16": 1.0, "int8_ef": factor},
                             "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5}}}}}
        path.write_text(json.dumps(doc))
        CM.load_wire_calibration(str(path))
        if factor == 1.0:
            t_full = w.t_reduce(chunk, base)
        else:
            t_half = w.t_reduce(chunk, base)
    CM.reset_wire_calibration()
    np.testing.assert_allclose(t_half, t_full * 0.5)


# ---------------------------------------------------------------------------
# autotuner integration
# ---------------------------------------------------------------------------
def test_autotuner_searches_manual_sync_on_dp_mesh():
    from repro.core import TPU_V5E, build_workload, search
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4,), ("data",)), TPU_V5E)
    res = search(w, compress="on", sync="manual", allow_host=False, allow_swap=False)
    assert res.feasible
    assert res.plan.sync_mode == "manual"
    assert res.plan.grad_compress == "int8_ef"
    assert res.plan.manual_sync_ok(w.mesh.tp_degree)

    # default search (compress="auto", sync="auto") must also succeed and only
    # ever emit lowerable plans
    res2 = search(w)
    assert res2.feasible
    if res2.plan.sync_mode == "manual":
        assert res2.plan.manual_sync_ok(w.mesh.tp_degree)
