"""Manual shard_map gradient sync (MemoryPlan.sync_mode="manual").

Covers the ISSUE-2 and ISSUE-3 acceptance criteria: numerics parity with the
xla path on a multi-device mesh (CI forces 4 CPU devices) for both manual
kinds — DDP-style replicated layouts and ZeRO-sharded layouts synced by the
compressed reduce-scatter — error-feedback residuals that carry across steps
(stacked per-device for replicated leaves, shard-sized for ZeRO leaves), s8
payloads visible in the compiled HLO (all-gathers for DDP, all-to-alls for
ZeRO), the 1-device fallback guard, the manual_sync_kind eligibility
lattice, the wire-cost calibration round trip, and the autotuner searching
sync_mode with calibrated factors."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import cost_model as CM
from repro.core.plan import MemoryPlan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.optim.adam import AdamConfig
from repro.train.step_builder import build_train_step

N_DEV = len(jax.devices())
TINY = reduced(ARCHS["llama3-405b"])
SHAPE = ShapeConfig("tiny", 32, 16, "train")  # local batch 16/N_DEV per device

needs_multi_device = pytest.mark.skipif(
    N_DEV < 2 or 16 % N_DEV != 0,
    reason="manual-vs-xla parity needs a multi-device mesh (CI forces 4)",
)


def dp_mesh(n=None):
    n = n if n is not None else N_DEV
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def run_steps(plan, mesh, steps=10, lr=3e-3, seed=0):
    art = build_train_step(TINY, plan, mesh, SHAPE, adam=AdamConfig(lr=lr))
    state = art.init(jax.random.PRNGKey(seed))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    losses, metrics = [], None
    for _ in range(steps):
        state, metrics = jfn(state, pipe.next_sync())
        losses.append(float(metrics["loss"]))
    return art, state, losses, metrics


def persist_plan(**kw):
    return MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4, **kw)


def zero_plan(n_persist=0, **kw):
    return MemoryPlan(n_chunks=4, n_blocks=2, n_persist=n_persist, **kw)


# ---------------------------------------------------------------------------
# numerics parity + EF carry-over
# ---------------------------------------------------------------------------
@needs_multi_device
@pytest.mark.parametrize("n_persist,zero_stage",
                         [(4, 3), (0, 2), (0, 3)],
                         ids=["ddp", "zero2", "zero3"])
def test_manual_matches_xla_losses_over_ten_steps(n_persist, zero_stage):
    """Acceptance (ISSUE-2 ddp, ISSUE-3 zero2, ISSUE-4 zero3): int8+EF manual
    sync tracks the xla path within bf16 tolerance over >= 10 steps for the
    replicated (gather-synced) layout and both ZeRO-sharded dataflows —
    up-front gather ("zero2") and lazy per-chunk gather with the
    reduce-scatter transpose ("zero3"). The paths quantize before vs after
    the reduce, so they are not bitwise equal — EF keeps them together."""
    mesh = dp_mesh()
    _, _, l_xla, _ = run_steps(
        zero_plan(n_persist, grad_compress="int8_ef", sync_mode="xla"), mesh)
    _, _, l_man, m_man = run_steps(
        zero_plan(n_persist, grad_compress="int8_ef", sync_mode="manual",
                  zero_stage=zero_stage), mesh)
    assert all(np.isfinite(l_man))
    # bf16 has ~8 mantissa bits: tolerate ~2 ulp of relative drift
    np.testing.assert_allclose(l_man, l_xla, rtol=2e-2)
    assert float(m_man["ef_norm"]) > 0


@needs_multi_device
def test_manual_int8_payload_is_on_the_wire():
    """The compiled manual program must move s8 payloads (real compression),
    and must contain no fp32 gradient all-reduce."""
    mesh = dp_mesh()
    art = build_train_step(
        TINY, persist_plan(grad_compress="int8_ef", sync_mode="manual"), mesh, SHAPE)
    hlo = art.lower(donate=False).compile().as_text()
    s8_gathers = [ln for ln in hlo.splitlines() if "all-gather(" in ln and "s8[" in ln]
    assert s8_gathers, "expected int8 all-gathers in the manual-sync HLO"


@needs_multi_device
@pytest.mark.parametrize("zero_stage", [2, 3], ids=["zero2", "zero3"])
def test_manual_zero_int8_reduce_scatter_on_the_wire_and_shard_ef(zero_stage):
    """Acceptance (ISSUE-3/4): a ZeRO-sharded manual plan compiles to s8
    scatter-equivalent collectives (all_to_all of the quantized chunks) in
    both dataflows, and its EF residuals are shard-sized on each device yet
    globally checkpointable (full logical shape, sharded layout)."""
    mesh = dp_mesh()
    plan = zero_plan(grad_compress="int8_ef", sync_mode="manual",
                     zero_stage=zero_stage)
    art = build_train_step(TINY, plan, mesh, SHAPE)
    hlo = art.lower(donate=False).compile().as_text()
    s8_a2a = [ln for ln in hlo.splitlines() if "all-to-all" in ln and "s8[" in ln]
    assert s8_a2a, "expected s8 all-to-alls (compressed reduce-scatter) in HLO"

    state = art.init(jax.random.PRNGKey(0))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    state, _ = jfn(state, pipe.next_sync())

    from repro.dist.sharding import leaf_sync_dim, zero_axes

    axes = zero_axes(mesh)
    ef_leaves = jax.tree.leaves(state["ef"])
    param_leaves = jax.tree.leaves(state["params"])
    sharded = 0
    for e, p in zip(ef_leaves, param_leaves):
        if e.shape == p.shape:
            # ZeRO-sharded residual: full logical (= param) shape, laid out
            # in the gradient's own sharded spec — checkpointable, and each
            # device holds only its 1/N_DEV shard
            d = leaf_sync_dim(e.sharding, axes)
            assert d is not None
            sharded += 1
            local = e.addressable_shards[0].data.shape
            assert local[d] == e.shape[d] // N_DEV
        else:
            # replicated leaf: stacked per-device residual, as in DDP
            assert e.shape == (N_DEV,) + p.shape
    assert sharded > 0, "zero plan should have ZeRO-sharded EF leaves"
    # the residuals are checkpoint round-trippable as plain arrays
    as_np = [np.asarray(e) for e in ef_leaves]
    assert any(np.abs(a).max() > 0 for a in as_np)


@needs_multi_device
def test_manual_ef_residual_carries_across_steps():
    mesh = dp_mesh()
    plan = persist_plan(grad_compress="int8_ef", sync_mode="manual")
    art, state, _, _ = run_steps(plan, mesh, steps=1)
    # manual EF is device-varying state, stored stacked (n_sync leading axis,
    # sharded over the sync axes) so checkpoints see every device's residual
    for leaf in jax.tree.leaves(state["ef"]):
        assert leaf.shape[0] == N_DEV
    ef1 = [np.asarray(x) for x in jax.tree.leaves(state["ef"])]
    assert any(np.abs(e).max() > 0 for e in ef1)  # quantization dropped something
    # the per-device slices genuinely differ (each fed back its own error)
    assert any(
        np.abs(e[0] - e[1]).max() > 0 for e in ef1 if e.shape[0] > 1
    )

    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=1)
    state2, _ = jfn(state, pipe.next_sync())
    ef2 = [np.asarray(x) for x in jax.tree.leaves(state2["ef"])]
    # the residual is live state: it keeps changing as new error feeds back
    assert any(np.abs(a - b).max() > 0 for a, b in zip(ef1, ef2))


@needs_multi_device
def test_manual_microbatch_sync_per_microbatch():
    mesh = dp_mesh()
    plan = persist_plan(grad_compress="int8_ef", sync_mode="manual",
                        microbatch=2)
    _, state, losses, metrics = run_steps(plan, mesh, steps=3)
    assert all(np.isfinite(losses))
    assert float(metrics["ef_norm"]) > 0


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------
def test_manual_one_device_mesh_falls_back_to_local_math():
    """Same guard policy as the mesh-size checks in dist/collectives.py: a
    1-device mesh takes the local math path (wire numerics, no collectives) —
    for both eligibility kinds."""
    mesh = dp_mesh(1)
    for plan in (persist_plan(grad_compress="int8_ef", sync_mode="manual"),
                 zero_plan(grad_compress="int8_ef", sync_mode="manual")):
        _, _, losses, metrics = run_steps(plan, mesh, steps=2)
        assert all(np.isfinite(losses))
        assert float(metrics["ef_norm"]) > 0


def test_manual_rejects_unlowerable_layouts():
    # eligibility is validated on every mesh size — including 1 device, so
    # locally-exercised code fails the same way it would deployed. ZeRO
    # plans lower since the sync-strategy layer; swap/host/zero1 still raise.
    bad = [
        zero_plan(n_swap=1, grad_compress="int8_ef", sync_mode="manual"),
        zero_plan(n_host=2, grad_compress="int8_ef", sync_mode="manual"),
        persist_plan(zero1_persistent=True, grad_compress="int8_ef",
                     sync_mode="manual"),
    ]
    for plan in bad:
        for n in {1, N_DEV}:
            with pytest.raises(ValueError, match="manual"):
                build_train_step(TINY, plan, dp_mesh(n), SHAPE)


def test_search_rejects_manual_sync_without_compression():
    from repro.core import TPU_V5E, build_workload, search
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4,), ("data",)), TPU_V5E)
    with pytest.raises(ValueError, match="manual"):
        search(w, compress="off", sync="manual")


LATTICE = [
    # (n_persist, n_host, n_swap, tp, dp_only, zero1) -> expected kind
    # (default zero_stage=3; the zero_stage=2 mapping is tested below)
    ((4, 0, 0, 1, False, False), "ddp"),
    ((4, 0, 0, 4, False, False), None),    # TP shards the params
    ((4, 0, 0, 4, True, False), "ddp"),    # dp_only absorbs the model axis
    ((0, 0, 0, 1, False, False), "zero3"),  # ISSUE-4: lazy gather by default
    ((2, 0, 0, 1, False, False), "zero3"),  # mixed persist/ZeRO
    ((0, 0, 0, 1, True, False), "zero3"),   # dp_only moot at tp=1
    ((0, 0, 0, 4, False, False), None),    # ZeRO + live TP axis: no kind
    ((0, 0, 0, 4, True, False), None),     # dp_only can't fix shard-axis
    ((0, 2, 0, 1, False, False), None),    # host memory kinds in shard_map
    ((4, 0, 1, 1, False, False), None),    # swap offload in shard_map
    ((0, 0, 1, 1, False, False), None),
    ((4, 0, 0, 1, False, True), None),     # zero1_persistent
    ((2, 0, 0, 1, False, True), None),
]


@pytest.mark.parametrize("cell,kind", LATTICE)
def test_manual_sync_kind_lattice(cell, kind):
    """manual_sync_kind over the plan lattice (persist x host x swap x TP x
    dp_only x zero1): ZeRO-sharded eligible plans report "zero3" (the lazy
    default), ineligible combinations still report None (and raise in
    build_train_step — see test_manual_rejects_unlowerable_layouts)."""
    n_persist, n_host, n_swap, tp, dp_only, zero1 = cell
    plan = MemoryPlan(4, 2, n_persist=n_persist, n_host=n_host, n_swap=n_swap,
                      dp_only=dp_only, zero1_persistent=zero1)
    assert plan.manual_sync_kind(tp_degree=tp) == kind
    # manual_sync_ok stays the "can lower at all" predicate
    assert plan.manual_sync_ok(tp) == (kind is not None)


@pytest.mark.parametrize("cell,kind", LATTICE)
def test_manual_sync_kind_lattice_zero_stage2(cell, kind):
    """zero_stage=2 flips only the ZeRO verdicts ("zero3" -> "zero2"); the
    ddp/None cells are independent of the dataflow knob."""
    n_persist, n_host, n_swap, tp, dp_only, zero1 = cell
    plan = MemoryPlan(4, 2, n_persist=n_persist, n_host=n_host, n_swap=n_swap,
                      dp_only=dp_only, zero1_persistent=zero1, zero_stage=2)
    expected = "zero2" if kind == "zero3" else kind
    assert plan.manual_sync_kind(tp_degree=tp) == expected


# ---------------------------------------------------------------------------
# wire-cost calibration: fit -> JSON -> cost model
# ---------------------------------------------------------------------------
def test_calibration_roundtrip(tmp_path):
    path = tmp_path / "wire_calibration.json"
    doc = {
        "generated_by": "test",
        "backends": {
            jax.default_backend(): {
                "wire_factors": {
                    "xla": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.9},
                    "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.3},
                },
                "ef_residual_factor": 2.5,
            }
        },
    }
    path.write_text(json.dumps(doc))
    try:
        entry = CM.load_wire_calibration(str(path))
        assert entry is not None
        assert CM.wire_factor("xla", "int8_ef") == 0.9
        assert CM.wire_factor("manual", "int8_ef") == 0.3
        assert CM.ef_residual_factor() == 2.5
    finally:
        CM.reset_wire_calibration()


def test_packaged_calibration_overrides_hardcoded_constant():
    """Acceptance: the autotuner's wire costs come from the calibration JSON,
    not the legacy GRAD_WIRE_FACTOR constant — the measured xla-path factor is
    1.0 (in-jit compression never touched the wire), where the constant
    claims 0.5."""
    CM.reset_wire_calibration()
    entry = CM.load_wire_calibration()
    assert entry is not None, "packaged src/repro/core/wire_calibration.json missing"
    assert CM.wire_factor("xla", "int8_ef") == 1.0
    assert CM.wire_factor("xla", "int8_ef") != CM.GRAD_WIRE_FACTOR["int8_ef"]
    assert CM.wire_factor("manual", "int8_ef") < 1.0  # real compression
    # the reduce-scatter pipeline's factor is calibrated too (ISSUE-3): the
    # s8 all_to_all payload is ~half the bf16 bytes at scatter topology
    assert CM.wire_factor("manual", "int8_ef_rs") < 1.0


def test_wire_factor_rs_falls_back_for_pre_zero_calibrations(tmp_path):
    """Calibration JSONs written before the reduce-scatter pipeline existed
    lack the int8_ef_rs key; wire_factor falls back to the analytic default
    instead of KeyError-ing the whole search."""
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"backends": {jax.default_backend(): {
        "wire_factors": {"xla": {"none": 1.0, "bf16": 1.0, "int8_ef": 1.0},
                         "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5}}}}}))
    try:
        CM.load_wire_calibration(str(path))
        assert CM.wire_factor("manual", "int8_ef_rs") == \
            CM.DEFAULT_WIRE_FACTORS["manual"]["int8_ef_rs"]
    finally:
        CM.reset_wire_calibration()


def test_t_reduce_zero_manual_uses_scatter_topology():
    """For a ZeRO-sharded chunk the manual int8 reduce moves (z-1)/z of the
    compressed bytes (all_to_all), vs the DDP gather pipeline's (z-1) full
    payloads for a persistent chunk — the new term the autotuner ranks with."""
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4, 1), ("data", "model")), TPU_V5E)
    chunk = w.chunks[1]
    z = w.mesh.zero_degree
    manual_zero = zero_plan(grad_compress="int8_ef", sync_mode="manual")
    manual_ddp = persist_plan(grad_compress="int8_ef", sync_mode="manual")
    t_rs = w.t_reduce(chunk, manual_zero)
    t_gather = w.t_reduce(chunk, manual_ddp)
    # same payload ratio, topologies differ by ~z: scatter divides by z
    np.testing.assert_allclose(t_gather / t_rs, z, rtol=0.1)
    # and the compressed reduce-scatter beats the uncompressed xla one
    t_xla = w.t_reduce(chunk, zero_plan(grad_compress="none", sync_mode="xla"))
    assert t_rs < t_xla


def test_t_reduce_uses_calibrated_factor(tmp_path):
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4, 1), ("data", "model")), TPU_V5E)
    chunk = w.chunks[1]
    base = persist_plan(grad_compress="int8_ef", sync_mode="xla")

    path = tmp_path / "cal.json"
    for factor in (1.0, 0.5):
        doc = {"backends": {jax.default_backend(): {
            "wire_factors": {"xla": {"none": 1.0, "bf16": 1.0, "int8_ef": factor},
                             "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5}}}}}
        path.write_text(json.dumps(doc))
        CM.load_wire_calibration(str(path))
        if factor == 1.0:
            t_full = w.t_reduce(chunk, base)
        else:
            t_half = w.t_reduce(chunk, base)
    CM.reset_wire_calibration()
    np.testing.assert_allclose(t_half, t_full * 0.5)


# ---------------------------------------------------------------------------
# autotuner integration
# ---------------------------------------------------------------------------
def test_autotuner_searches_manual_sync_on_dp_mesh():
    from repro.core import TPU_V5E, build_workload, search
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4,), ("data",)), TPU_V5E)
    res = search(w, compress="on", sync="manual", allow_host=False, allow_swap=False)
    assert res.feasible
    assert res.plan.sync_mode == "manual"
    assert res.plan.grad_compress == "int8_ef"
    assert res.plan.manual_sync_ok(w.mesh.tp_degree)

    # default search (compress="auto", sync="auto") must also succeed and only
    # ever emit lowerable plans
    res2 = search(w)
    assert res2.feasible
    if res2.plan.sync_mode == "manual":
        assert res2.plan.manual_sync_ok(w.mesh.tp_degree)


def test_autotuner_emits_zero_manual_when_persist_does_not_fit():
    """ISSUE-3: manual candidates are no longer all-persist-or-nothing — when
    the replicated layout busts capacity, the search emits a ZeRO-sharded
    manual plan (kind "zero") ranked with the reduce-scatter wire term."""
    from repro.core import TPU_V5E, build_workload, estimate_memory, search
    from repro.core.hardware import MeshSpec

    w = build_workload(TINY, SHAPE, MeshSpec((4,), ("data",)), TPU_V5E)
    full = estimate_memory(
        w, persist_plan(grad_compress="int8_ef", sync_mode="manual")).peak
    lo = estimate_memory(
        w, zero_plan(grad_compress="int8_ef", sync_mode="manual")).peak
    assert lo < full  # sharding the states must save memory
    cap = (lo + full) / 2
    res = search(w, capacity_bytes=cap, compress="on", sync="manual",
                 allow_host=False, allow_swap=False)
    assert res.feasible
    assert res.plan.sync_mode == "manual"
    assert res.plan.n_persist < w.n_chunks
    assert res.plan.manual_sync_kind(w.mesh.tp_degree) in ("zero2", "zero3")
    assert res.memory.peak < cap
