"""ISSUE-6: chunked prefill admission + page-boundary flush + engine API.

Covers the acceptance criteria:
  * chunked prefill (``serve/prefill.py``) produces caches and logits
    **bitwise-identical** to token-by-token teacher-forced replay — resident
    and paged (flush enabled) caches, uneven chunk splits, staggered
    per-slot prompt lengths, and a sliding-window ring-wrap prompt longer
    than the ring;
  * the page-boundary flush (``PagedKV(flush=True)``) is logit-equivalent
    to the old per-token write-through;
  * the scheduler's prefill/decode interleaving never starves an in-flight
    stream more than ``chunk_budget`` consecutive prefill ticks (property
    test), preserving the page-ledger invariants;
  * the engine's three admission modes produce identical finished streams;
  * the serve_load harness workload and drive loop are deterministic.

The one documented exception: jamba's mamba ssm-state reduction
reassociates under the prefill scan fusion (<= 1 ulp in the recurrent
state); logits and attention cache leaves stay bitwise.
"""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.plan import MemoryPlan
from repro.launch.mesh import make_local_mesh
from repro.models import kvcache as KV
from repro.models import model as M
from repro.serve import (
    ContinuousScheduler,
    DecodeEngine,
    PagedKV,
    PagePool,
    Request,
    choose_paging,
    init_paged_cache,
    prefill_chunk,
)


def _replay_tokens(params, cache, tokens, pos, n_tok, cfg, kv_io=None):
    """Token-by-token teacher-forced reference: one decode_step per token
    with the same per-slot active masking the prefill scan applies."""
    _, c = tokens.shape
    step = jax.jit(lambda ca, t, p, a: KV.decode_step(
        params, ca, t, p, cfg, kv_io=kv_io, active=a))
    last = jnp.zeros((tokens.shape[0], cfg.vocab_size), jnp.dtype(cfg.dtype))
    n = jnp.asarray(n_tok, jnp.int32)
    base = jnp.asarray(pos, jnp.int32)
    for t in range(c):
        logits, cache = step(cache, tokens[:, t:t + 1], base + t, t < n)
        last = jnp.where((t == n - 1)[:, None], logits, last)
    return last, cache


def _prefill_in_chunks(params, cache, tokens, pos, n_tok, cfg, chunks,
                       kv_io=None):
    """Drive ``prefill_chunk`` over an (uneven) chunk split of the block —
    exactly what the engine's prefill ticks do across calls."""
    assert sum(chunks) == tokens.shape[1]
    last = jnp.zeros((tokens.shape[0], cfg.vocab_size), jnp.dtype(cfg.dtype))
    n = jnp.asarray(n_tok, jnp.int32)
    base = jnp.asarray(pos, jnp.int32)
    run = jax.jit(lambda ca, blk, p, nb: prefill_chunk(
        params, ca, blk, p, nb, cfg, kv_io=kv_io))
    off = 0
    for c in chunks:
        nb = jnp.clip(n - off, 0, c)
        lg, cache = run(cache, tokens[:, off:off + c], base + off, nb)
        last = jnp.where(((n > off) & (n <= off + c))[:, None], lg, last)
        off += c
    return last, cache


def _leaf_diffs(tree_a, tree_b):
    """[(path, max |a-b|)] over aligned leaves (exact in f32 for bf16)."""
    fa = jax.tree_util.tree_flatten_with_path(tree_a)[0]
    fb = jax.tree_util.tree_flatten_with_path(tree_b)[0]
    assert len(fa) == len(fb)
    out = []
    for (path, x), (_, y) in zip(fa, fb):
        d = float(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)).max())
        out.append((jax.tree_util.keystr(path), d))
    return out


def _decode_a_while(params, cache, cfg, start_pos, steps, kv_io=None,
                    seed=9):
    """Teacher-forced continuation: the post-prefill decode logits are where
    a cache mismatch would surface."""
    b = start_pos.shape[0]
    step = jax.jit(lambda ca, t, p: KV.decode_step(
        params, ca, t, p, cfg, kv_io=kv_io))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, steps), 1,
                              cfg.vocab_size)
    outs = []
    for t in range(steps):
        logits, cache = step(cache, toks[:, t:t + 1],
                             jnp.asarray(start_pos, jnp.int32) + t)
        outs.append(logits)
    return outs, cache


def _parity_case(cfg, S, n_tok, chunks, kv_io_factory, decode_steps=4):
    """Replay vs chunked prefill on fresh caches; returns (last-logits diff,
    per-leaf cache diffs, per-step decode-logit diffs)."""
    b = len(n_tok)
    block = max(n_tok)
    assert sum(chunks) >= block
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, sum(chunks)), 1,
                                cfg.vocab_size)
    pos = [0] * b
    io_r = kv_io_factory()
    cache_r = (KV.init_cache(cfg, b, S) if io_r is None
               else init_paged_cache(cfg, b, S, io_r.spec))
    last_r, cache_r = _replay_tokens(params, cache_r, tokens, pos, n_tok,
                                     cfg, kv_io=io_r)
    io_c = kv_io_factory()
    cache_c = (KV.init_cache(cfg, b, S) if io_c is None
               else init_paged_cache(cfg, b, S, io_c.spec))
    last_c, cache_c = _prefill_in_chunks(params, cache_c, tokens, pos, n_tok,
                                         cfg, chunks, kv_io=io_c)

    logit_diff = float(jnp.abs(last_r.astype(jnp.float32)
                               - last_c.astype(jnp.float32)).max())
    cache_diffs = _leaf_diffs(cache_r, cache_c)
    start = jnp.asarray(n_tok, jnp.int32)
    out_r, _ = _decode_a_while(params, cache_r, cfg, start, decode_steps,
                               kv_io=io_r)
    out_c, _ = _decode_a_while(params, cache_c, cfg, start, decode_steps,
                               kv_io=io_c)
    dec_diffs = [float(jnp.abs(a.astype(jnp.float32)
                               - c.astype(jnp.float32)).max())
                 for a, c in zip(out_r, out_c)]
    return logit_diff, cache_diffs, dec_diffs


def test_chunked_prefill_matches_replay_resident():
    """Full attention, resident cache, staggered prompt lengths, uneven
    chunk split: everything bitwise, through 4 more decode steps."""
    cfg = reduced(get_config("llama3-405b"))
    logit_d, cache_d, dec_d = _parity_case(
        cfg, S=64, n_tok=[5, 16, 9, 12], chunks=[6, 6, 4],
        kv_io_factory=lambda: None)
    assert logit_d == 0.0, f"prefill logits diverged from replay: {logit_d}"
    bad = [(p, d) for p, d in cache_d if d != 0.0]
    assert not bad, f"prefill cache diverged from replay: {bad}"
    assert all(d == 0.0 for d in dec_d), f"post-prefill decode diverged: {dec_d}"


def test_chunked_prefill_matches_replay_paged_flush():
    """Paged cache with the page-boundary flush on (the production spec):
    prefill chunks cross flush boundaries and stay bitwise replay-exact."""
    cfg = reduced(get_config("llama3-405b"))
    spec = choose_paging(KV.cache_len(cfg, 64), 8, 2)
    assert spec.n_cold > 0
    logit_d, cache_d, dec_d = _parity_case(
        cfg, S=64, n_tok=[5, 16, 9, 12], chunks=[5, 7, 4],
        kv_io_factory=lambda: PagedKV(spec))
    assert logit_d == 0.0, f"paged prefill logits diverged: {logit_d}"
    bad = [(p, d) for p, d in cache_d if d != 0.0]
    assert not bad, f"paged prefill cache diverged: {bad}"
    assert all(d == 0.0 for d in dec_d), f"post-prefill decode diverged: {dec_d}"


def test_chunked_prefill_swa_ring_wrap():
    """Sliding-window ring cache (mixtral), prompts longer than the ring:
    the prefill scan wraps the ring mid-chunk and still matches replay."""
    cfg = reduced(get_config("mixtral-8x22b"))
    assert cfg.sliding_window
    s_kv = KV.cache_len(cfg, 96)
    n = s_kv + 22  # wrap the ring well past one full cycle
    spec = choose_paging(s_kv, 8, 2)
    chunks = [16] * (n // 16) + ([n % 16] if n % 16 else [])
    logit_d, cache_d, dec_d = _parity_case(
        cfg, S=96, n_tok=[n, n - 15, n, n - 9], chunks=chunks,
        kv_io_factory=lambda: PagedKV(spec))
    assert logit_d == 0.0, f"SWA ring-wrap prefill diverged: {logit_d}"
    bad = [(p, d) for p, d in cache_d if d != 0.0]
    assert not bad, f"SWA ring-wrap cache diverged: {bad}"
    assert all(d == 0.0 for d in dec_d), f"post-wrap decode diverged: {dec_d}"


def test_chunked_prefill_hybrid_mamba_logits_exact():
    """Jamba: prefill logits and attention cache leaves are bitwise; the
    mamba ssm reduction reassociates under the scan fusion (<= 1 ulp of
    recurrent state — the documented exception; attention-free configs
    default to replay admission for this reason)."""
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    logit_d, cache_d, _ = _parity_case(
        cfg, S=64, n_tok=[5, 12, 7, 10], chunks=[5, 7],
        kv_io_factory=lambda: None, decode_steps=0)
    assert logit_d == 0.0, f"hybrid prefill logits diverged: {logit_d}"
    for path, d in cache_d:
        if "conv" in path or "ssm" in path:
            assert d <= 1e-5, f"mamba state drifted beyond ulp noise: {path} {d}"
        else:
            assert d == 0.0, f"attention leaf diverged: {path} {d}"


@pytest.mark.parametrize("per_slot", [False, True])
def test_flush_matches_write_through(per_slot):
    """PagedKV(flush=True) vs the legacy per-token write-through: logits
    bitwise-equal every step, across page boundaries and the ring wrap."""
    cfg = reduced(get_config("mixtral-8x22b"))
    B, S, steps = 4, 96, 90
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    caches = {f: init_paged_cache(cfg, B, S, spec) for f in (True, False)}
    stepfns = {f: jax.jit(lambda c, t, p, f=f: KV.decode_step(
        params, c, t, p, cfg, kv_io=PagedKV(spec, flush=f)))
        for f in (True, False)}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, steps), 1,
                              cfg.vocab_size)
    for t in range(steps):
        pos = jnp.full((B,), t, jnp.int32) if per_slot else jnp.int32(t)
        lf, caches[True] = stepfns[True](caches[True], toks[:, t:t + 1], pos)
        lw, caches[False] = stepfns[False](caches[False], toks[:, t:t + 1], pos)
        d = float(jnp.abs(lf.astype(jnp.float32)
                          - lw.astype(jnp.float32)).max())
        assert d == 0.0, f"flush diverged from write-through at step {t}: {d}"


# ---------------------------------------------------------------------------
# Scheduler interleaving property: chunked prefill never starves a stream
# ---------------------------------------------------------------------------
def _check_pages(sched: ContinuousScheduler):
    pool = sched.pool
    held = sum(pool.held_by(b) for b in range(sched.n_slots))
    assert pool.n_free + held == pool.n_pages, "page leak"
    for b, s in enumerate(sched.slots):
        if s is None:
            assert pool.held_by(b) == 0, f"freed slot {b} still owns pages"


@settings(max_examples=25, deadline=None)
@given(
    n_slots=st.integers(min_value=2, max_value=4),
    chunk=st.integers(min_value=1, max_value=6),
    budget=st.integers(min_value=1, max_value=3),
    prompts=st.lists(st.tuples(st.integers(min_value=1, max_value=20),
                               st.integers(min_value=1, max_value=5)),
                     min_size=1, max_size=8),
)
def test_interleaving_never_starves_decode(n_slots, chunk, budget, prompts):
    """Replicates the engine loop host-side: while any decode-ready stream
    exists, at most ``chunk_budget`` consecutive prefill ticks run before a
    decode tick (``should_prefill``), the ledger invariants hold through
    ``advance_prefill``, and the system drains."""
    page_size, cache_len = 4, 24
    pool = PagePool((cache_len // page_size) * n_slots)
    sched = ContinuousScheduler(n_slots, pool, page_size, cache_len)
    sched.submit([Request(i, list(range(1, p + 1)), m)
                  for i, (p, m) in enumerate(prompts)])
    consec = starved = ticks = 0
    while not sched.idle and ticks < 2000:
        sched.admit()
        decode_waiting = bool(sched.decode_ready())
        if sched.should_prefill(consec, budget):
            for b in list(sched.prefill_slots()):
                s = sched.slots[b]
                if s is not None:
                    sched.ensure_pages(
                        b, s.length + min(chunk, sched.prefill_budget(b)))
            fed = [0] * n_slots
            for b in sched.prefill_slots():
                fed[b] = min(chunk, sched.prefill_budget(b))
            if any(fed):
                sched.advance_prefill(fed, [1] * n_slots)
            consec += 1
            if decode_waiting:
                starved = max(starved, consec)
        else:
            _, _, active = sched.step_inputs(replay_prefill=False)
            if any(active):
                sched.advance([2] * n_slots, active)
            consec = 0
        _check_pages(sched)
        ticks += 1
    assert sched.idle, f"did not drain in {ticks} ticks"
    assert starved <= budget, \
        f"a decode-ready stream waited {starved} consecutive prefill ticks"


# ---------------------------------------------------------------------------
# Engine: the three admission modes produce identical streams
# ---------------------------------------------------------------------------
def test_engine_admission_modes_agree():
    """replay / chunked / whole on the paged plan: the finished token
    streams must be identical — chunked prefill is replay-exact and greedy
    decode is deterministic."""
    cfg = reduced(get_config("llama3-405b"))
    B, S = 4, 64
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    plan = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3, n_host=spec.n_cold)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    lens = [4, 20, 9, 30, 6]
    toks = jax.random.randint(key, (len(lens), max(lens)), 1, cfg.vocab_size)

    def requests():
        return [Request(i, [int(t) for t in toks[i, :n]], 6)
                for i, n in enumerate(lens)]

    results = {}
    for mode in ("replay", "chunked", "whole"):
        eng = DecodeEngine(cfg, plan, mesh, shape, params, paging=spec,
                           admission=mode, prefill_chunk=8)
        rep = eng.run(requests())
        assert rep.drained and not rep.rejected
        if mode != "replay":
            assert rep.prefill_ticks > 0
        results[mode] = rep.finished
    assert results["replay"] == results["chunked"] == results["whole"]


def test_engine_stream_yields_every_token():
    """stream() emits each finished request's tokens exactly once, in
    index order, with the final token flagged."""
    cfg = reduced(get_config("llama3-405b"))
    B, S = 4, 64
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    plan = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = [Request(i, [7 + i, 3, 11], 5) for i in range(3)]
    eng = DecodeEngine(cfg, plan, mesh, shape, params)
    got: dict[int, list[int]] = {}
    final: dict[int, int] = {}
    for ev in eng.stream(reqs):
        got.setdefault(ev.rid, [])
        assert ev.index == len(got[ev.rid]), "events out of order"
        got[ev.rid].append(ev.token)
        if ev.finished:
            final[ev.rid] = ev.index
    rep = eng.report()
    assert got == rep.finished
    assert final == {rid: len(t) - 1 for rid, t in rep.finished.items()}


# ---------------------------------------------------------------------------
# serve_load harness: deterministic workload + drive loop
# ---------------------------------------------------------------------------
def _load_serve_load():
    path = pathlib.Path(__file__).parent.parent / "benchmarks" / "serve_load.py"
    spec = importlib.util.spec_from_file_location("serve_load", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_serve_load_smoke_determinism():
    """The load harness's seeded workload is reproducible, and driving a
    chunked engine over it twice yields identical checksums/tick counts."""
    sl = _load_serve_load()
    w1 = sl.build_workload(5, 6, 500)
    w2 = sl.build_workload(5, 6, 500)
    assert [(t, r.rid, r.prompt_tokens, r.max_new_tokens) for t, r in w1] \
        == [(t, r.rid, r.prompt_tokens, r.max_new_tokens) for t, r in w2]

    cfg = reduced(get_config("llama3-405b"))
    B, S = 4, 48
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    plan = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3, n_host=spec.n_cold)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    runs = [sl.run_mode("chunked", cfg, plan, mesh, shape, params, spec,
                        sl.build_workload(5, 6, cfg.vocab_size), 8, 2000)
            for _ in range(2)]
    assert runs[0]["drained"] and runs[1]["drained"]
    for key in ("token_checksum", "steps", "prefill_ticks", "decode_ticks",
                "generated_tokens"):
        assert runs[0][key] == runs[1][key], f"nondeterministic {key}"
