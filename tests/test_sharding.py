"""Direct coverage of the repro.dist substrate: ParamDef->spec mapping,
placement memory kinds, dp_only collapse, batch/gather/activation shardings,
collective portability across 1- and N-device CPU meshes, and the int8+EF
compressed-gradient training path end to end.

Runs under any local device count; CI forces 4 CPU devices via
XLA_FLAGS=--xla_force_host_platform_device_count=4 so the multi-device
branches are exercised there."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import host_memory_kind
from repro.dist import collectives as COLL
from repro.dist import sharding as SH
from repro.models.layers import LAYER, NONE, TP, ZERO, ParamDef

N_DEV = len(jax.devices())


def mesh2d():
    """(data, model) mesh over all local devices, data-major."""
    model = 2 if N_DEV % 2 == 0 and N_DEV >= 2 else 1
    return jax.make_mesh((N_DEV // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _expect(mesh, dim, axes):
    """Axis entry the sharder should emit: kept iff the extent divides dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = math.prod(sizes[a] for a in axes)
    if n != 1 and (dim % n or dim < n):
        return None
    return axes[0] if len(axes) == 1 else axes


# ---------------------------------------------------------------------------
# sharding_for: axis-tag mapping per placement
# ---------------------------------------------------------------------------
def test_spec_zero_tp_by_placement():
    mesh = mesh2d()
    d = ParamDef((16, 32), (ZERO, TP))
    assert SH.sharding_for(d, mesh, placement="hbm").spec == P("data", "model")
    assert SH.sharding_for(d, mesh, placement="persist").spec == P(None, "model")
    # host keeps the hbm partitioning, only the memory kind changes
    assert SH.sharding_for(d, mesh, placement="host").spec == P("data", "model")


def test_spec_dp_only_collapses_tp():
    mesh = mesh2d()
    d = ParamDef((16, 32), (ZERO, TP))
    assert SH.sharding_for(d, mesh, placement="hbm", dp_only=True).spec == P("data", None)
    assert SH.sharding_for(d, mesh, placement="persist", dp_only=True).spec == P(None, None)
    # batch takes every axis in dp_only mode
    assert SH.batch_axes(mesh, True) == tuple(mesh.axis_names)
    assert SH.batch_axes(mesh, False) == ("data",)


def test_spec_untagged_and_layer_dims_never_shard():
    mesh = mesh2d()
    d = ParamDef((3, 16, 32), (LAYER, ZERO, TP))
    assert SH.sharding_for(d, mesh, placement="hbm").spec == P(None, "data", "model")
    norm = ParamDef((16,), (NONE,))
    assert SH.sharding_for(norm, mesh, placement="hbm").spec == P(None)


def test_spec_indivisible_dim_stays_replicated():
    mesh = mesh2d()
    d = ParamDef((7, 9), (ZERO, TP))
    expect = P(_expect(mesh, 7, ("data",)), _expect(mesh, 9, ("model",)))
    assert SH.sharding_for(d, mesh, placement="hbm").spec == expect


def test_host_placement_memory_kind_and_roundtrip():
    mesh = mesh2d()
    d = ParamDef((8, 8), (ZERO, TP), dtype="float32")
    s = SH.sharding_for(d, mesh, placement="host")
    kind = host_memory_kind(mesh)
    if kind is None:
        pytest.skip("platform exposes no host memory space")
    assert s.memory_kind == kind  # pinned_host on TPU/GPU, unpinned_host on CPU
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    hosted = jax.device_put(x, s)
    assert hosted.sharding.memory_kind == kind
    # gather_sharding brings it back to device memory, ZeRO axes dropped
    g = SH.gather_sharding(d, mesh)
    assert g.spec == P(None, "model")
    back = jax.device_put(hosted, g)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# ---------------------------------------------------------------------------
# tree variants
# ---------------------------------------------------------------------------
def test_tree_specs_carry_shapes_dtypes_shardings():
    mesh = mesh2d()
    defs = {"a": ParamDef((8, 16), (ZERO, TP)),
            "n": ParamDef((16,), (NONE,), dtype="float32")}
    sh = SH.tree_shardings(defs, mesh, placement="hbm")
    specs = SH.tree_specs(defs, sh)
    assert specs["a"].shape == (8, 16) and specs["a"].dtype == jnp.bfloat16
    assert specs["n"].dtype == jnp.float32
    assert specs["a"].sharding is sh["a"]


def test_tree_gather_shardings_strip_layer_axis():
    mesh = mesh2d()
    stacked = {"w": ParamDef((3, 8, 16), (LAYER, ZERO, TP))}
    g = SH.tree_gather_shardings(stacked, mesh)
    assert g["w"].spec == P(None, "model")  # per-repeat rank, ZeRO gathered
    assert SH.tree_gather_shardings(stacked, mesh, persistent=True) is None


def test_batch_sharding_rank_handling():
    mesh = mesh2d()
    assert SH.batch_sharding(mesh, 2).spec == P("data", None)
    assert SH.batch_sharding(mesh, 3).spec == P("data", None, None)
    assert SH.batch_sharding(mesh, 2, dp_only=True).spec == P(
        ("data", "model") if "model" in mesh.axis_names else "data", None
    )


def test_activation_sharder_is_identity_math():
    from repro.core.plan import MemoryPlan

    mesh = mesh2d()
    plan = MemoryPlan(n_chunks=4, n_blocks=2, seq_shard_acts=True)
    sharder = SH.make_activation_sharder(mesh, plan)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))
    for kind in ("bsd", "enter", "logits"):
        np.testing.assert_array_equal(np.asarray(sharder(x, kind)), np.asarray(x))


# ---------------------------------------------------------------------------
# collectives: portable across 1-device and forced-multi-device meshes
# ---------------------------------------------------------------------------
def full_mesh():
    return jax.make_mesh((N_DEV,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def test_bf16_all_reduce_any_device_count():
    x = jnp.linspace(-3, 3, 256, dtype=jnp.float32)
    out = COLL.bf16_all_reduce(x, full_mesh())
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x.astype(jnp.bfloat16), np.float32), atol=2e-2
    )


def test_compressed_all_reduce_any_device_count():
    x = jax.random.normal(jax.random.PRNGKey(3), (513,), jnp.float32)
    err0 = jnp.zeros_like(x)
    avg, err1 = COLL.compressed_all_reduce(x, err0, full_mesh())
    np.testing.assert_allclose(np.asarray(avg + err1), np.asarray(x), atol=1e-5)
    # residual bounded by half a quantization step
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.abs(err1).max()) <= scale / 2 + 1e-6


def test_compressed_tree_all_reduce_roundtrip():
    tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((8,), -2.0)}}
    errs = COLL.init_error_feedback(tree)
    avg, new_err = COLL.compressed_tree_all_reduce(tree, errs)
    assert jax.tree.structure(avg) == jax.tree.structure(tree)
    total = jax.tree.map(lambda a, e: a + e, avg, new_err)
    for got, want in zip(jax.tree.leaves(total), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# int8+EF gradient compression through the real train step
# ---------------------------------------------------------------------------
def test_train_step_with_int8_ef_compression():
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeConfig
    from repro.core.plan import MemoryPlan
    from repro.data.pipeline import SyntheticTokenPipeline
    from repro.optim.adam import AdamConfig
    from repro.train.step_builder import build_train_step

    tiny = reduced(ARCHS["llama3-405b"])
    shape = ShapeConfig("tiny", 32, 4, "train")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    plan = MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4, grad_compress="int8_ef")
    art = build_train_step(tiny, plan, mesh, shape, adam=AdamConfig(lr=3e-3))
    assert "ef" in art.state_specs  # error-feedback residuals live in the state
    state = art.init(jax.random.PRNGKey(0))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(tiny, shape, seed=0)
    losses = []
    for _ in range(30):
        state, metrics = jfn(state, pipe.next_sync())
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert "ef_norm" in metrics and float(metrics["ef_norm"]) > 0
    assert np.mean(losses[-5:]) < np.mean(losses[:5])  # still learns under EF


def test_autotuner_exposes_compression_knob():
    from repro.configs import TRAIN_4K, get_config
    from repro.core import SINGLE_POD, TPU_V5E, build_workload, search
    from repro.core.cost_model import estimate_runtime
    from repro.core.plan import MemoryPlan

    w = build_workload(get_config("stablelm-3b"), TRAIN_4K, SINGLE_POD, TPU_V5E)
    res = search(w, compress="on")
    assert res.feasible and res.plan.grad_compress == "int8_ef"
    # halved reduce wire bytes can never slow the modeled iteration down
    base = MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=w.n_blocks)
    comp = MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=w.n_blocks,
                      grad_compress="int8_ef")
    assert (estimate_runtime(w, comp).t_iteration
            <= estimate_runtime(w, base).t_iteration + 1e-9)
