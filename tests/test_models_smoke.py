"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs. Full configs are only exercised via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced, shapes_for
from repro.models import model as M
from repro.models import kvcache as KV

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def make_batch(cfg, key=KEY, batch=B, seq=S):
    dt = jnp.dtype(cfg.dtype)
    batch_d = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
    if cfg.kind == "encdec":
        batch_d["frames"] = jax.random.normal(key, (batch, seq, cfg.d_model), dt)
    if cfg.frontend == "vision_patches":
        batch_d["patches"] = jax.random.normal(key, (batch, 8, cfg.d_model), dt)
    return batch_d


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_no_nan(name):
    cfg = reduced(ARCHS[name])
    params = M.init_params(cfg, KEY)
    h, aux = M.forward(params, make_batch(cfg), cfg)
    assert h.shape == (B, S, cfg.d_model)
    assert not jnp.any(jnp.isnan(h.astype(jnp.float32)))
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_decreases_loss(name):
    """One SGD step on the reduced config should be finite and reduce loss."""
    cfg = reduced(ARCHS[name])
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        h, aux = M.forward(p, batch, cfg)
        logits = M.lm_head(p, h, cfg).astype(jnp.float32)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
        return nll + aux

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(l0)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    l1 = loss_fn(params2)
    assert jnp.isfinite(l1)
    assert l1 < l0 + 1e-3  # non-increase (small step)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step_matches_prefill(name):
    """Decoding token-by-token must match the teacher-forced forward pass.

    Run in fp32: in bf16, tiny path differences flip MoE top-k routing
    decisions and amplify — algorithmic equivalence is what we assert here.
    """
    import dataclasses

    cfg = dataclasses.replace(reduced(ARCHS[name]), dtype="float32")
    if cfg.frontend == "vision_patches":
        pytest.skip("decode tested on the LM part only for VLM")
    params = M.init_params(cfg, KEY)
    seq = 16
    batch = make_batch(cfg, seq=seq)
    h, _ = M.forward(params, batch, cfg, attn_impl="naive")
    logits_ref = M.lm_head(params, h, cfg).astype(jnp.float32)

    cache = KV.init_cache(cfg, B, seq)
    if cfg.kind == "encdec":
        # prime cross-attention cache from the encoder output
        memory = M.encode(params, batch["frames"], cfg)
        p = M.superblock_period(cfg)
        r = M.num_repeats(cfg)
        hd = cfg.resolved_head_dim
        for j in range(p):
            ap = params["blocks"][f"pos{j}"]["xattn"]
            xk = jnp.einsum("bsd,rdk->rbsk", memory, ap["wk"]).reshape(
                r, B, seq, cfg.num_kv_heads, hd
            )
            xv = jnp.einsum("bsd,rdk->rbsk", memory, ap["wv"]).reshape(
                r, B, seq, cfg.num_kv_heads, hd
            )
            cache[f"pos{j}"]["xk"] = xk.astype(cache[f"pos{j}"]["xk"].dtype)
            cache[f"pos{j}"]["xv"] = xv.astype(cache[f"pos{j}"]["xv"].dtype)

    step = jax.jit(lambda c, t, p: KV.decode_step(params, c, t, p, cfg))
    outs = []
    for t in range(seq):
        logits, cache = step(cache, batch["tokens"][:, t : t + 1], jnp.int32(t))
        outs.append(logits)
    logits_dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    assert logits_dec.shape == logits_ref.shape
    err = jnp.abs(logits_dec - logits_ref).max() / (jnp.abs(logits_ref).max() + 1e-6)
    assert err < 2e-3, f"decode/prefill mismatch {err}"


def test_sliding_window_cache_is_ring():
    cfg = reduced(ARCHS["mixtral-8x22b"], sliding_window=8)
    specs = KV.cache_specs(cfg, B, 64)
    assert specs["pos0"]["k"].shape[2] == 8  # ring of window size, not 64


def test_mamba_cache_is_constant_size():
    cfg = reduced(ARCHS["mamba2-130m"])
    s1 = KV.cache_specs(cfg, B, 64)
    s2 = KV.cache_specs(cfg, B, 4096)
    assert jax.tree.map(lambda a: a.shape, s1) == jax.tree.map(lambda a: a.shape, s2)
