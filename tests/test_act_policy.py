"""Per-block activation policies (ISSUE-9).

Tentpole acceptance beyond the plan/cost-model unit checks:

  * quantize-on-save / dequantize-on-use (models/model.compress_act) is a
    faithful save format: 10-step loss parity against the exact (keep-all)
    run within bf16 tolerance for compress8, compress16, and mixed vectors,
    on BOTH sync paths (xla sharded and manual zero3 lazy-gather);
  * the compression is real, not just modeled: on the deeper 8-layer toy the
    compiled XLA buffer assignment keeps strictly less temp memory live for
    a compress8 plan than for keep-all;
  * the greedy policy search (autotuner.search_act_policies) is
    deterministic and, at a budget where keep-all is infeasible, its vector
    models a strictly lower step time than uniform remat-all — the best
    feasible uniform policy;
  * the scalar knobs (n_checkpoint / n_swap) lower onto the vector without
    behavior change, so every pre-ISSUE-9 plan string and test stays valid;
  * the calibration JSON stays forward-compatible: files predating the
    ``act_compress`` factor load with the analytic default.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import cost_model as CM
from repro.core.plan import MemoryPlan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import compress_act
from repro.optim.adam import AdamConfig
from repro.train.step_builder import build_train_step

N_DEV = len(jax.devices())
TINY = reduced(ARCHS["llama3-405b"])
SHAPE = ShapeConfig("tiny", 32, 16, "train")
DEEP = dataclasses.replace(reduced(ARCHS["llama3-405b"]), num_layers=8,
                           d_model=256, d_ff=1024, vocab_size=1024)

needs_multi_device = pytest.mark.skipif(
    N_DEV < 2 or 16 % N_DEV != 0,
    reason="parity cells assume the CI mesh (4 forced CPU devices)",
)


def dp_mesh(n=None):
    n = n if n is not None else N_DEV
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def run_steps(plan, mesh, steps=10, lr=3e-3, seed=0):
    art = build_train_step(TINY, plan, mesh, SHAPE, adam=AdamConfig(lr=lr))
    state = art.init(jax.random.PRNGKey(seed))
    jfn = jax.jit(art.fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(TINY, SHAPE, seed=0)
    losses = []
    for _ in range(steps):
        state, metrics = jfn(state, pipe.next_sync())
        losses.append(float(metrics["loss"]))
    return losses


# ---------------------------------------------------------------------------
# plan lowering / describe
# ---------------------------------------------------------------------------
def test_scalar_knobs_lower_to_uniform_vector():
    """n_checkpoint/n_swap and an equivalent explicit vector agree block by
    block, so the vector is a strict generalization of the scalar plans."""
    scalar = MemoryPlan(n_chunks=4, n_blocks=4, n_checkpoint=2)
    vector = MemoryPlan(n_chunks=4, n_blocks=4,
                        act_policies=("checkpoint", "checkpoint",
                                      "none", "none"))
    assert scalar.block_policies() == vector.block_policies()
    for b in range(4):
        assert scalar.block_policy(b) == vector.block_policy(b)


def test_policy_aliases_and_validation():
    p = MemoryPlan(n_chunks=4, n_blocks=2, act_policies=("keep", "remat"))
    assert tuple(p.block_policies()) == ("none", "checkpoint")
    assert p.compressed_blocks() == 0
    q = MemoryPlan(n_chunks=4, n_blocks=2,
                   act_policies=("compress8", "compress16"))
    assert q.compressed_blocks() == 2
    with pytest.raises(AssertionError):
        MemoryPlan(n_chunks=4, n_blocks=2, act_policies=("none",))  # length
    with pytest.raises(AssertionError):
        MemoryPlan(n_chunks=4, n_blocks=2, act_policies=("none", "fp4"))
    with pytest.raises(AssertionError):  # vector and scalar knobs conflict
        MemoryPlan(n_chunks=4, n_blocks=2, n_checkpoint=1,
                   act_policies=("none", "none"))


def test_describe_reports_policy_vector_overlap_and_zero_stage():
    man = MemoryPlan(n_chunks=4, n_blocks=2, grad_compress="int8_ef",
                     sync_mode="manual", zero_stage=3)
    d = man.describe()
    assert "zstage=3" in d and "overlap=on" in d
    ser = dataclasses.replace(man, overlap=False).describe()
    assert "overlap=off" in ser
    grp = MemoryPlan(n_chunks=4, n_blocks=4, n_checkpoint=4,
                     ckpt_group=2).describe()
    assert "ckptg=2" in grp
    vec = MemoryPlan(n_chunks=4, n_blocks=4,
                     act_policies=("compress8", "compress8", "checkpoint",
                                   "none")).describe()
    assert "acts=compress8x2,checkpoint,none" in vec


# ---------------------------------------------------------------------------
# compress seam round-trip (hypothesis)
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1),
       mode=st.sampled_from(["compress8", "compress16"]))
@settings(max_examples=15, deadline=None)
def test_compress_act_roundtrip_and_straight_through_grad(seed, mode):
    """The quantize-on-save custom_vjp: dequantized values stay within the
    format's tolerance (int8 absmax rowwise: half an LSB of the row scale;
    bf16 downcast: one bf16 ulp), and the gradient is exactly the identity
    (straight-through to the uncompressed input — AD never sees the kernel)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, 3, 32), jnp.float32) * 3.0
    y = np.asarray(compress_act(x, mode))
    xr = np.asarray(x).reshape(-1, 32)
    if mode == "compress8":
        scale = np.maximum(np.abs(xr).max(axis=1), 1e-30) / 127.0
        tol = (scale * 0.5 + 1e-7)[:, None]
    else:
        tol = np.abs(xr) * 2.0 ** -8 + 1e-7
    np.testing.assert_array_less(np.abs(y.reshape(-1, 32) - xr),
                                 np.broadcast_to(tol, xr.shape))

    w = jax.random.normal(jax.random.fold_in(key, 1), x.shape, jnp.float32)
    g = jax.grad(lambda x: jnp.sum(compress_act(x, mode) * w))(x)
    # compress8's straight-through is exact; compress16's cotangent rides the
    # bf16 downcast pair, so the identity holds to one bf16 ulp
    rtol = 1e-6 if mode == "compress8" else 2.0 ** -7
    np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=rtol)


# ---------------------------------------------------------------------------
# end-to-end parity (tentpole acceptance)
# ---------------------------------------------------------------------------
@needs_multi_device
@pytest.mark.parametrize("pols", [
    ("compress8", "compress8"),
    ("compress16", "compress16"),
    ("compress8", "checkpoint"),
], ids=lambda p: "+".join(p))
def test_xla_loss_parity_compressed_vs_exact(pols):
    """10-step loss parity: a compressed-activation plan trains within bf16
    noise of the exact keep-all plan on the xla sharded path."""
    mesh = dp_mesh()
    exact = run_steps(MemoryPlan(n_chunks=4, n_blocks=2), mesh)
    comp = run_steps(
        MemoryPlan(n_chunks=4, n_blocks=2, act_policies=pols), mesh)
    np.testing.assert_allclose(comp, exact, rtol=2e-2)


@needs_multi_device
def test_manual_zero3_loss_parity_compressed_vs_exact():
    """Same parity on the manual zero3 lazy-gather path — the compress
    policy must compose with _save_acts_not_lazy_gathers (save_only keeps
    int8 payloads, re-gathers weights, never quantizes a gather)."""
    mesh = dp_mesh()
    exact = run_steps(MemoryPlan(n_chunks=4, n_blocks=2), mesh)
    comp = run_steps(
        MemoryPlan(n_chunks=4, n_blocks=2, grad_compress="int8_ef",
                   sync_mode="manual", zero_stage=3,
                   act_policies=("compress8", "compress8")), mesh)
    np.testing.assert_allclose(comp, exact, rtol=2e-2)


@needs_multi_device
def test_compress_shrinks_measured_temp_memory_vs_keep():
    """The compression is real in the compiled program: on the 8-layer toy
    XLA's buffer assignment holds strictly less temp memory for uniform
    compress8 than for keep-all (int8 payloads live FWD->BWD instead of the
    full-width activations)."""
    mesh = dp_mesh()
    shape = ShapeConfig("deep", 32, 16, "train")
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    w = build_workload(DEEP, shape, MeshSpec((N_DEV, 1), ("data", "model")),
                       TPU_V5E)
    keep = MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks)
    comp = dataclasses.replace(
        keep, act_policies=("compress8",) * w.n_blocks)

    def temp_bytes(plan):
        art = build_train_step(DEEP, plan, mesh, shape)
        return art.lower().compile().memory_analysis().temp_size_in_bytes

    t_keep, t_comp = temp_bytes(keep), temp_bytes(comp)
    assert t_comp < t_keep, (
        f"compress8 temp {t_comp / 1e6:.1f}MB not below "
        f"keep-all {t_keep / 1e6:.1f}MB")


# ---------------------------------------------------------------------------
# cost model + search
# ---------------------------------------------------------------------------
def _deep_workload():
    from repro.core import TPU_V5E, build_workload
    from repro.core.hardware import MeshSpec

    return build_workload(DEEP, ShapeConfig("fid", 32, 16, "train"),
                          MeshSpec((4,), ("data",)), TPU_V5E)


def test_cost_model_orders_policies():
    """Per block the model prices: memory keep > compress8 > remat (saved
    bytes) and time remat > compress8 > keep (recompute + passes) — the
    ordering the greedy ladder exploits."""
    w = _deep_workload()
    nc, nb = w.n_chunks, w.n_blocks
    mk = lambda pol: MemoryPlan(  # noqa: E731
        nc, nb, n_persist=nc, act_policies=(pol,) * nb)
    mem = {p: CM.estimate_memory(w, mk(p)).peak
           for p in ("none", "compress8", "checkpoint")}
    rt = {p: CM.estimate_runtime(w, mk(p)).t_iteration
          for p in ("none", "compress8", "checkpoint")}
    assert mem["checkpoint"] < mem["compress8"] < mem["none"]
    assert rt["none"] < rt["compress8"] < rt["checkpoint"]
    # compress16 keeps twice the bytes of compress8 for the same recompute
    m16 = CM.estimate_memory(w, mk("compress16")).peak
    assert mem["compress8"] < m16 < mem["none"]


def test_act_policy_search_deterministic_and_beats_uniform_remat():
    """At a budget bracketed strictly between the remat-all and keep-all
    peaks, the searched vector fits and models a strictly lower step time
    than uniform remat-all (the best feasible uniform policy); two searches
    return the identical plan."""
    from repro.core.autotuner import search_act_policies

    w = _deep_workload()
    nc, nb = w.n_chunks, w.n_blocks
    keep = MemoryPlan(nc, nb, n_persist=nc)
    remat = dataclasses.replace(keep, n_checkpoint=nb)
    budget = 0.5 * (CM.estimate_memory(w, keep).peak
                    + CM.estimate_memory(w, remat).peak)
    assert CM.estimate_memory(w, keep).peak > budget  # keep-all infeasible

    r1 = search_act_policies(w, keep, capacity_bytes=budget)
    r2 = search_act_policies(w, keep, capacity_bytes=budget)
    assert r1.plan == r2.plan
    assert r1.feasible
    assert CM.estimate_memory(w, r1.plan).peak < budget
    t_remat = CM.estimate_runtime(w, remat).t_iteration
    assert r1.runtime.t_iteration < t_remat


def test_megatrain_plan_fits_single_pod_capacity():
    """MegaTrain satellite: the all-host optimizer tier plans a 100B+ model
    under HardwareSpec.capacity_bytes() on the single production pod —
    every chunk on the host tier, nothing persistent, activations degraded
    until the footprint fits."""
    from repro.configs import get_config, get_shape
    from repro.core import TPU_V5E, SINGLE_POD, build_workload
    from repro.core.autotuner import megatrain_plan

    cfg = get_config("llama3-405b")
    assert cfg.param_count() >= 100e9
    w = build_workload(cfg, get_shape("train_4k"), SINGLE_POD, TPU_V5E)
    plan = megatrain_plan(w)
    assert plan.host_optimizer and not plan.host_params
    assert plan.n_host == w.n_chunks and plan.n_persist == 0
    assert CM.estimate_memory(w, plan).peak < TPU_V5E.capacity_bytes()


# ---------------------------------------------------------------------------
# calibration forward-compat
# ---------------------------------------------------------------------------
def test_calibration_without_act_compress_defaults(tmp_path):
    """A calibration JSON predating the act_compress factor loads without
    KeyError; the factor resolves to the analytic default until refit."""
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"version": 2, "backends": {
        jax.default_backend(): {"wire_factors": {
            "xla": {"none": 1.0, "bf16": 1.0, "int8_ef": 1.0},
            "manual": {"none": 1.0, "bf16": 1.0, "int8_ef": 0.5},
        }}}}))
    try:
        assert CM.load_wire_calibration(str(path)) is not None
        assert CM.wire_factor("manual", "act_compress") == \
            CM.DEFAULT_WIRE_FACTORS["manual"]["act_compress"]
        assert CM.wire_factor("xla", "act_compress") == \
            CM.DEFAULT_WIRE_FACTORS["xla"]["act_compress"]
        assert CM.wire_factor("manual", "int8_ef") == 0.5
    finally:
        CM.reset_wire_calibration()
