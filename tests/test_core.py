"""ProTrain core tests: chunks, profiler, cost models, plan invariants."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, TRAIN_4K, get_config, reduced
from repro.core import (
    MemoryPlan,
    SINGLE_POD,
    MULTI_POD,
    TPU_V5E,
    build_workload,
    chunk_inventory,
    chunk_size_search,
    estimate_memory,
    estimate_runtime,
    profile_fn,
    search,
)
from repro.core.chunks import chunk_waste, pack_into_chunks
from repro.core.plan import fully_resident_plan


# ---------------------------------------------------------------------------
# chunks
# ---------------------------------------------------------------------------
def test_chunk_inventory_execution_order():
    cfg = get_config("llama3-405b")
    inv = chunk_inventory(cfg)
    assert inv[0].name == "embed"
    assert inv[-1].name == "head"
    assert [c.block_index for c in inv if c.is_block] == list(range(126))
    # total params ~405B
    total = sum(c.param_count for c in inv)
    assert 3.9e11 < total < 4.2e11, total


def test_chunk_16_bytes_per_param():
    cfg = get_config("stablelm-3b")
    inv = chunk_inventory(cfg)
    c = inv[1]
    # bf16 param + bf16 grad + fp32 (master, m, v) = 16 B/param (paper §1)
    assert c.param_bytes + c.grad_bytes + c.optim_bytes == 16 * c.param_count


@given(
    sizes=st.lists(st.integers(min_value=1, max_value=10_000), min_size=1, max_size=60),
    chunk=st.integers(min_value=8, max_value=4096),
)
@settings(max_examples=60, deadline=None)
def test_packing_preserves_params_and_order(sizes, chunk):
    packed = pack_into_chunks(sizes, chunk)
    flat = [s for c in packed for s in c]
    assert flat == sizes  # nothing lost, order preserved (execution order!)
    assert chunk_waste(sizes, chunk) >= 0


def test_chunk_size_search_prefers_low_waste():
    sizes = [1000] * 64
    best, waste = chunk_size_search(sizes, candidates=[1000, 1024, 3000])
    assert best == 1000 and waste == 0


def test_chunk_waste_oversized_params_are_exact_fit():
    """Regression for the collapsed max()/if in chunk_waste: params larger
    than the chunk get a dedicated exact-fit chunk — zero padding — and do
    not poison neighboring chunks' accounting."""
    # one oversized param alone: dedicated chunk, no waste
    assert chunk_waste([5000], 1024) == 0
    # exactly chunk-sized: also exact fit
    assert chunk_waste([1024], 1024) == 0
    # oversized between small params: small ones pad, the big one never does
    sizes = [600, 5000, 600]
    packed = pack_into_chunks(sizes, 1024)
    assert [sum(c) for c in packed] == [600, 5000, 600]
    assert chunk_waste(sizes, 1024) == (1024 - 600) * 2
    # all-oversized stream: zero waste regardless of chunk size
    assert chunk_waste([2048, 4096, 8192], 1024) == 0


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------
def test_profiler_matmul_flops_exact():
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    p = profile_fn(f, a, b)
    assert abs(p.total_flops - 2 * 64 * 128 * 32) < 64 * 32 + 10


def test_profiler_scan_trip_count():
    """Scan body costs must be multiplied by length (XLA cost_analysis bug)."""

    def f(w, x):
        def body(c, wi):
            return c @ wi, None

        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    w = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    p = profile_fn(f, w, x)
    expect = 7 * 2 * 8 * 32 * 32
    assert abs(p.total_flops - expect) / expect < 0.05


def test_profiler_residual_classification():
    def f(w, x):
        return jnp.tanh(x @ w).sum()

    w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    p = profile_fn(f, w, x, weight_args=(0,))
    assert p.residual_weight_bytes == 128 * 256 * 4  # w saved for d(x@w)/dx
    assert p.residual_act_bytes >= 8 * 128 * 4  # x saved for d(x@w)/dw


# ---------------------------------------------------------------------------
# plan invariants (property-based)
# ---------------------------------------------------------------------------
@given(
    nc=st.integers(2, 40),
    nb=st.integers(1, 38),
    data=st.data(),
)
@settings(max_examples=80, deadline=None)
def test_plan_partitions_are_total(nc, nb, data):
    nb = min(nb, nc - 1)
    n_persist = data.draw(st.integers(0, nc))
    n_host = data.draw(st.integers(0, nc - n_persist))
    n_buffer = data.draw(st.integers(0, nc - n_persist))
    n_swap = data.draw(st.integers(0, nb))
    n_ckpt = data.draw(st.integers(0, nb - n_swap))
    plan = MemoryPlan(nc, nb, n_persist=n_persist, n_buffer=n_buffer, n_host=n_host,
                      n_swap=n_swap, n_checkpoint=n_ckpt)
    places = [plan.chunk_placement(i) for i in range(nc)]
    assert all(p in ("persist", "hbm", "host") for p in places)
    assert places.count("persist") == n_persist
    assert places.count("host") <= n_host  # host range may overlap persist? no:
    pols = plan.block_policies()
    assert pols.count("swap") == n_swap
    assert pols.count("checkpoint") == n_ckpt
    # interleaved layout ordering: swap first, then checkpoint, then none
    first_none = pols.index("none") if "none" in pols else len(pols)
    assert all(p != "swap" for p in pols[first_none:])


@pytest.fixture(scope="module")
def llama_workload():
    return build_workload(get_config("llama3-405b"), TRAIN_4K, SINGLE_POD, TPU_V5E)


def test_memory_monotone_in_persist(llama_workload):
    w = llama_workload
    peaks = [
        estimate_memory(w, MemoryPlan(w.n_chunks, w.n_blocks, n_persist=k, n_checkpoint=w.n_blocks)).peak
        for k in (0, 8, 32, 128)
    ]
    assert peaks == sorted(peaks)


def test_memory_monotone_in_checkpoint(llama_workload):
    w = llama_workload
    peaks = [
        estimate_memory(w, MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=k)).peak
        for k in (126, 64, 16, 0)
    ]
    assert peaks == sorted(peaks)  # fewer checkpointed blocks -> more memory


def test_host_offload_reduces_memory(llama_workload):
    w = llama_workload
    m0 = estimate_memory(w, MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=w.n_blocks)).peak
    m1 = estimate_memory(
        w, MemoryPlan(w.n_chunks, w.n_blocks, n_host=w.n_chunks, n_checkpoint=w.n_blocks)
    ).peak
    assert m1 < m0


def test_runtime_checkpointing_costs_time(llama_workload):
    w = llama_workload
    t0 = estimate_runtime(w, MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks)).t_iteration
    t1 = estimate_runtime(
        w, MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks, n_checkpoint=w.n_blocks)
    ).t_iteration
    assert t1 > t0  # recompute overhead (Eq. 5 T_recomp)


def test_buffering_reduces_bwd_time(llama_workload):
    w = llama_workload
    base = MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=w.n_blocks)
    buf = MemoryPlan(w.n_chunks, w.n_blocks, n_buffer=w.n_chunks, n_checkpoint=w.n_blocks)
    assert estimate_runtime(w, buf).t_bwd <= estimate_runtime(w, base).t_bwd


def test_sp_reduces_activation_memory(llama_workload):
    w = llama_workload
    p = MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=w.n_blocks)
    psp = MemoryPlan(w.n_chunks, w.n_blocks, n_checkpoint=w.n_blocks, seq_shard_acts=True)
    assert estimate_memory(w, psp).activations < estimate_memory(w, p).activations


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------
def test_search_returns_feasible_plans_for_all_archs():
    for name in sorted(ARCHS):
        w = build_workload(get_config(name), TRAIN_4K, SINGLE_POD, TPU_V5E)
        res = search(w, sp="auto")
        assert res.feasible, name
        assert res.memory.peak < TPU_V5E.hbm_bytes * 0.92, name
        assert res.runtime.t_iteration > 0


def test_search_respects_capacity():
    w = build_workload(get_config("stablelm-3b"), TRAIN_4K, SINGLE_POD, TPU_V5E)
    tight = search(w, capacity_bytes=4e9)
    loose = search(w, capacity_bytes=15e9)
    assert tight.memory.peak < 4e9
    # looser budget must never be slower (more freedom)
    assert loose.runtime.t_iteration <= tight.runtime.t_iteration + 1e-9


def test_search_multi_pod_mesh():
    w = build_workload(get_config("mixtral-8x22b"), TRAIN_4K, MULTI_POD, TPU_V5E)
    res = search(w, sp="auto")
    assert res.feasible


def test_fully_resident_small_model():
    w = build_workload(get_config("mamba2-130m"), TRAIN_4K, SINGLE_POD, TPU_V5E)
    res = search(w)
    # small model: tuner should park everything on device, no remat/offload
    assert res.plan.n_host == 0
    assert res.plan.n_checkpoint == 0
    assert res.plan.n_persist == res.plan.n_chunks
