"""ISSUE-5: paged KV-cache serving subsystem.

Covers the acceptance criteria:
  * host-paged decode produces logits *identical* (bitwise) to resident
    decode over >= 32 generated tokens, full-attention and sliding-window
    (ring wrap) cases, on the 4-device CI mesh — scalar and per-slot
    positions;
  * the continuous-batching scheduler leaks no slots or pages across
    admit/evict/finish cycles (property tests, hypothesis or the
    repro.testing fallback stub);
  * serve_plan emits a paged candidate (n_host > 0) whenever the resident
    cache exceeds the HBM budget while the weights still fit;
  * the decode engine serves a request stream with identical results under
    resident and paged plans, reporting a real HBM cache reduction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.hardware import HardwareSpec, LOCAL_CPU_HW, MeshSpec
from repro.core.plan import MemoryPlan
from repro.core.serve_plan import paging_from_plan, serve_memory_estimate, serve_plan
from repro.launch.mesh import make_local_mesh
from repro.models import kvcache as KV
from repro.models import model as M
from repro.serve import (
    ContinuousScheduler,
    DecodeEngine,
    PagePool,
    PagedKV,
    Request,
    choose_paging,
    init_paged_cache,
)

MESH1 = MeshSpec((1, 1), ("data", "model"))


def _drive_parity(cfg, B, S, steps, page, hot, per_slot=False):
    spec = choose_paging(KV.cache_len(cfg, S), page, hot)
    assert spec.n_cold > 0, "parity must exercise cold fetches"
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache_r = KV.init_cache(cfg, B, S)
    cache_p = init_paged_cache(cfg, B, S, spec)
    io = PagedKV(spec)
    step_r = jax.jit(lambda c, t, p: KV.decode_step(params, c, t, p, cfg))
    step_p = jax.jit(lambda c, t, p: KV.decode_step(params, c, t, p, cfg, kv_io=io))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, steps), 0, cfg.vocab_size)
    worst = 0.0
    for t in range(steps):
        pos = jnp.full((B,), t, jnp.int32) if per_slot else jnp.int32(t)
        lr, cache_r = step_r(cache_r, toks[:, t:t + 1], pos)
        lp, cache_p = step_p(cache_p, toks[:, t:t + 1], pos)
        worst = max(worst, float(jnp.abs(lr - lp).max()))
    return worst


@pytest.mark.parametrize("per_slot", [False, True])
def test_paged_decode_parity_full_attention(per_slot):
    cfg = reduced(get_config("llama3-405b"))
    diff = _drive_parity(cfg, B=4, S=64, steps=40, page=8, hot=2, per_slot=per_slot)
    assert diff == 0.0, f"paged decode diverged from resident: {diff}"


@pytest.mark.parametrize("hot", [1, 2, 4])
def test_paged_decode_parity_sliding_window_ring(hot):
    """Mixtral's ring cache: decode far past the window so the ring wraps
    and the steady-state every-slot-valid mask exercises stale-row rules."""
    cfg = reduced(get_config("mixtral-8x22b"))
    assert cfg.sliding_window, "config must ring-buffer"
    diff = _drive_parity(cfg, B=4, S=96, steps=90, page=8, hot=hot)
    assert diff == 0.0, f"SWA paged decode diverged: {diff}"


def test_paged_decode_parity_hybrid_mamba_resident():
    """Jamba: attention positions page, mamba state stays O(1)-resident."""
    cfg = reduced(get_config("jamba-1.5-large-398b"))
    diff = _drive_parity(cfg, B=4, S=64, steps=40, page=8, hot=2)
    assert diff == 0.0, f"hybrid paged decode diverged: {diff}"


def test_paged_step_builder_parity_on_ci_mesh():
    """build_decode_step(paging=...) on the forced 4-device mesh: the full
    jit path with host memory kinds, >= 32 tokens, identical samples."""
    cfg = reduced(get_config("llama3-405b"))
    B, S = 4, 64
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    plan_r = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3)
    plan_p = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3, n_host=spec.n_cold)
    from repro.train.step_builder import build_decode_step

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    art_r = build_decode_step(cfg, plan_r, mesh, shape)
    art_p = build_decode_step(cfg, plan_p, mesh, shape, paging=spec)
    # cold leaves really live in the platform's host memory space
    from repro.compat import host_memory_kind

    kind = host_memory_kind(mesh)
    if kind is not None:
        for entry in art_p.state_shardings["cache"].values():
            assert entry["k_cold"].memory_kind == kind
            assert entry["v_cold"].memory_kind == kind
    step_r = jax.jit(art_r.fn)
    step_p = jax.jit(art_p.fn)
    cache_r = jax.tree.map(jax.device_put, KV.init_cache(cfg, B, S),
                           art_r.state_shardings["cache"])
    cache_p = init_paged_cache(cfg, B, S, spec,
                               shardings=art_p.state_shardings["cache"])
    st_r = {"params": params, "cache": cache_r}
    st_p = {"params": params, "cache": cache_p}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 33), 0, cfg.vocab_size)
    for t in range(33):
        batch = {"tokens": toks[:, t:t + 1], "pos": jnp.int32(t)}
        st_r, nr = step_r(st_r, batch)
        st_p, np_ = step_p(st_p, batch)
        assert bool((nr == np_).all()), f"sampled tokens diverged at step {t}"


# ---------------------------------------------------------------------------
# Scheduler properties: no slot/page leaks across admit/evict/finish cycles
# ---------------------------------------------------------------------------
def _check_invariants(sched: ContinuousScheduler, submitted: set[int]):
    pool = sched.pool
    held = sum(pool.held_by(b) for b in range(sched.n_slots))
    assert pool.n_free + held == pool.n_pages, "page leak"
    assert len(pool._owner) == held, "orphaned page ownership"
    for b, s in enumerate(sched.slots):
        if s is None:
            assert pool.held_by(b) == 0, f"freed slot {b} still owns pages"
        else:
            assert pool.held_by(b) >= 1, f"live slot {b} owns no pages"
    live = {s.rid for s in sched.slots if s is not None}
    queued = {r.rid for r in sched.queue}
    done = set(sched.finished) | set(sched.rejected)
    assert live | queued | done == submitted, "request leaked or invented"
    assert not (live & done) and not (queued & done), "request in two states"


@settings(max_examples=30, deadline=None)
@given(
    n_slots=st.integers(min_value=1, max_value=4),
    pool_pages=st.integers(min_value=1, max_value=12),
    page_size=st.integers(min_value=1, max_value=4),
    reqs=st.lists(
        st.tuples(st.integers(min_value=1, max_value=5),   # prompt len
                  st.integers(min_value=1, max_value=6)),  # max_new
        min_size=1, max_size=8),
    evict_every=st.integers(min_value=0, max_value=5),
)
def test_scheduler_no_slot_or_page_leaks(n_slots, pool_pages, page_size,
                                         reqs, evict_every):
    cache_len = 16
    sched = ContinuousScheduler(n_slots, PagePool(pool_pages), page_size, cache_len)
    submitted = set()
    for i, (pl, mn) in enumerate(reqs):
        sched.submit([Request(i, list(range(1, pl + 1)), mn)])
        submitted.add(i)
    for step in range(200):
        if sched.idle:
            break
        sched.admit()
        _check_invariants(sched, submitted)
        toks, _, _ = sched.step_inputs()
        sched.advance([t + 1 for t in toks])
        if evict_every and step % evict_every == evict_every - 1:
            sched._evict_youngest()
        _check_invariants(sched, submitted)
    # every request reached a terminal state (finished or rejected)
    assert sched.idle, "scheduler failed to drain"
    assert set(sched.finished) | set(sched.rejected) == submitted


def test_scheduler_finishes_exact_token_counts():
    sched = ContinuousScheduler(2, PagePool(8), 4, 16)
    sched.submit([Request(0, [1, 2, 3], 4), Request(1, [5], 2), Request(2, [9, 9], 3)])
    for _ in range(100):
        if sched.idle:
            break
        sched.admit()
        toks, _, _ = sched.step_inputs()
        sched.advance([t + 1 for t in toks])
    assert {k: len(v) for k, v in sched.finished.items()} == {0: 4, 1: 2, 2: 3}


# ---------------------------------------------------------------------------
# Planner: paged candidates + memory estimate
# ---------------------------------------------------------------------------
def _tight_hw(hbm_gb: float) -> HardwareSpec:
    return dataclasses.replace(LOCAL_CPU_HW, hbm_bytes=hbm_gb * 1e9,
                               host_bw=1e12)  # fast link: fetch feasible


def test_serve_plan_emits_paged_candidate_when_cache_overflows():
    cfg = reduced(get_config("llama3-405b"), num_layers=4)
    shape = ShapeConfig("serve", 32_768, 64, "decode")
    # generous HBM: resident; tight HBM (cache >> weights): paged
    roomy = serve_plan(cfg, shape, MESH1, _tight_hw(1000.0))
    assert roomy.n_persist == roomy.n_chunks and roomy.n_host == 0
    tight = serve_plan(cfg, shape, MESH1, _tight_hw(3.0))
    assert tight.n_host > 0, "resident cache exceeds budget: must page"
    assert tight.n_persist == tight.n_chunks, "weights fit: stay persistent"
    spec = paging_from_plan(cfg, shape, tight)
    assert spec is not None and spec.n_cold == tight.n_host
    est = serve_memory_estimate(cfg, shape, MESH1, tight)
    resident_est = serve_memory_estimate(
        cfg, shape, MESH1, MemoryPlan(tight.n_chunks, tight.n_blocks,
                                      n_persist=tight.n_chunks))
    assert est["peak_gb"] < resident_est["peak_gb"], "paging must shrink HBM"
    assert est["host_cache_gb"] > 0
    assert est["peak_gb"] < _tight_hw(3.0).capacity_bytes() / 1e9


def test_serve_plan_prefers_larger_hot_windows_on_faster_links():
    cfg = reduced(get_config("llama3-405b"), num_layers=4)
    shape = ShapeConfig("serve", 32_768, 64, "decode")
    slow = dataclasses.replace(_tight_hw(3.0), host_bw=1e6)
    fast = _tight_hw(3.0)
    p_slow, p_fast = (serve_plan(cfg, shape, MESH1, h) for h in (slow, fast))
    # both page; the slow link cannot make any window feasible, so it falls
    # back to the largest *fitting* window — never more cold pages than fast
    assert p_slow.n_host > 0 and p_fast.n_host > 0
    assert p_slow.n_host <= p_fast.n_host or p_slow.n_host == p_fast.n_host


def test_serve_plan_shards_weights_when_weights_overflow():
    cfg = reduced(get_config("llama3-405b"), num_layers=4)
    shape = ShapeConfig("serve", 1024, 8, "decode")  # tiny cache
    hw = dataclasses.replace(LOCAL_CPU_HW, hbm_bytes=2e6)  # weights >> hbm
    plan = serve_plan(cfg, shape, MESH1, hw)
    assert plan.n_persist == 0 and plan.n_host == 0


def test_page_fetch_feasibility_mirrors_drain_check():
    from repro.core.cost_model import page_fetch_feasible, t_page_fetch

    cfg = reduced(get_config("llama3-405b"), num_layers=4)
    shape = ShapeConfig("serve", 32_768, 64, "decode")
    spec = choose_paging(KV.cache_len(cfg, shape.seq_len), 256, 4)
    fast = dataclasses.replace(LOCAL_CPU_HW, host_bw=1e13)
    slow = dataclasses.replace(LOCAL_CPU_HW, host_bw=1e3)
    assert page_fetch_feasible(cfg, shape, MESH1, fast, spec)
    assert not page_fetch_feasible(cfg, shape, MESH1, slow, spec)
    assert t_page_fetch(cfg, shape, MESH1, slow, spec) > t_page_fetch(
        cfg, shape, MESH1, fast, spec)


# ---------------------------------------------------------------------------
# Engine: continuous batching end-to-end, resident == paged
# ---------------------------------------------------------------------------
def test_engine_continuous_batching_resident_matches_paged():
    cfg = reduced(get_config("llama3-405b"))
    B, S = 4, 64
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    plan_r = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3)
    plan_p = MemoryPlan(n_chunks=3, n_blocks=2, n_persist=3, n_host=spec.n_cold)
    mk = lambda: [Request(i, [7 + i, 11, 13 + i], 5 + i) for i in range(6)]  # noqa: E731
    rep_r = DecodeEngine(cfg, plan_r, mesh, shape, params).run(mk())
    rep_p = DecodeEngine(cfg, plan_p, mesh, shape, params, paging=spec).run(mk())
    assert rep_r.finished == rep_p.finished, "paged engine diverged"
    assert set(rep_r.finished) == set(range(6))
    assert all(len(v) == 5 + i for i, v in sorted(rep_r.finished.items()))
    assert rep_p.hbm_cache_bytes < rep_p.resident_cache_bytes
    assert rep_p.host_cache_bytes > 0


def test_engine_sliding_window_wraps_past_cache_length():
    """Ring caches keep generating past the window (slot reuse); paged and
    resident engines agree through the wrap and nothing is truncated."""
    cfg = reduced(get_config("mixtral-8x22b"))
    B, S = 2, 48  # cache_len = min(sliding_window=64, 48) = 48
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    mk = lambda: [Request(0, [5, 9], 60)]  # 62 tokens total > 48 slots  # noqa: E731
    rep_r = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3), mesh, shape,
                         params).run(mk())
    rep_p = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3, n_host=spec.n_cold),
                         mesh, shape, params, paging=spec).run(mk())
    assert rep_r.truncated == () and rep_p.truncated == ()
    assert len(rep_r.finished[0]) == 60
    assert rep_r.finished == rep_p.finished


def test_engine_full_attention_truncates_at_cache_exhaustion():
    cfg = reduced(get_config("llama3-405b"))
    B, S = 2, 16
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rep = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3), mesh, shape,
                       params).run([Request(0, [5, 9], 30)])
    assert rep.truncated == (0,), "cache exhaustion must be reported"
    assert len(rep.finished[0]) < 30
    assert rep.drained


def test_engine_staggered_admission_matches_dedicated_runs():
    """Requests admitted mid-stream (continuous batching) must decode the
    same tokens as a dedicated single-request engine run."""
    cfg = reduced(get_config("llama3-405b"))
    B, S = 2, 64
    mesh = make_local_mesh()
    shape = ShapeConfig("serve", S, B, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = [Request(i, [3 + 2 * i, 17 + i], 6) for i in range(4)]
    batched = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3), mesh, shape,
                           params).run([Request(r.rid, list(r.prompt), 6)
                                        for r in reqs])
    for r in reqs:
        solo = DecodeEngine(cfg, MemoryPlan(3, 2, n_persist=3), mesh, shape,
                            params).run([Request(r.rid, list(r.prompt), 6)])
        assert solo.finished[r.rid] == batched.finished[r.rid], (
            f"request {r.rid}: continuous batching changed its tokens")
