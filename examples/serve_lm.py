"""Continuous-batching LM serving demo: resident vs host-paged KV cache.

Serves a synthetic request stream through the decode engine and reports the
per-device HBM cache footprint of the chosen plan — the paged plan keeps a
hot window in HBM and pages the cold cache to host memory, which is the
point: long-context decode stops being bounded by HBM.

    PYTHONPATH=src python examples/serve_lm.py --plan paged --seq-len 128 \
        --requests 4 --max-new 8 --page-size 16 --hot-pages 2

``--plan resident`` runs the fully HBM-resident baseline; ``--plan paged``
forces the page-table cache; CI runs both as the serve-paged-parity gate
(the sampled tokens must match across plans for identical request streams).
``--admission`` picks how prompts enter the cache: ``chunked`` (default for
attentive configs) interleaves prefill chunks with decode ticks, ``whole``
runs each prompt's prefill to completion, ``replay`` teacher-forces the
prompt one token per tick (default for attention-free configs).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.compat import ensure_jax_compat

ensure_jax_compat()

from repro import obs  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core.plan import MemoryPlan  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.models import kvcache as KV  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve import DecodeEngine, Request, choose_paging  # noqa: E402


def build_requests(n: int, vocab: int, max_new: int) -> list[Request]:
    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (n, 4), 1, vocab)
    return [Request(i, [int(t) for t in prompts[i]], max_new) for i in range(n)]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-405b")
    ap.add_argument("--plan", choices=["resident", "paged"], default="paged")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hot-pages", type=int, default=2)
    ap.add_argument("--admission", default="auto",
                    choices=["auto", "replay", "chunked", "whole"],
                    help="prompt ingestion: chunked prefill interleaved "
                         "with decode (default for attentive configs), "
                         "whole-prompt prefill, or teacher-forced replay")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="override the cost-model prefill chunk size")
    ap.add_argument("--compiled-memory", action="store_true",
                    help="also AOT-compile the step to report XLA's per-"
                         "device argument bytes (a second full compile)")
    ap.add_argument("--log-jsonl", default=None, metavar="PATH",
                    help="also append every log line as a structured JSONL "
                         "record (obs.StructuredLogger)")
    args = ap.parse_args()
    log = obs.StructuredLogger("serve_lm", jsonl_path=args.log_jsonl)

    cfg = reduced(get_config(args.arch))
    mesh = make_local_mesh()
    n_dev = mesh.devices.size
    shape = ShapeConfig("serve", args.seq_len, args.batch_slots, "decode")
    s_kv = KV.cache_len(cfg, args.seq_len)

    paging = None
    nc, nb = 3, 2  # embed + blocks + head (labels the plan; weights persist)
    if args.plan == "paged":
        paging = choose_paging(s_kv, args.page_size, args.hot_pages)
        plan = MemoryPlan(nc, nb, n_persist=nc, n_host=paging.n_cold)
        log.info("plan",
                 f"[serve_lm] paged: {paging} "
                 f"(hot {paging.hot_window}/{s_kv} tokens, "
                 f"{paging.n_cold} cold pages -> host)",
                 plan="paged", hot_window=paging.hot_window,
                 n_cold=paging.n_cold, s_kv=s_kv)
    else:
        plan = MemoryPlan(nc, nb, n_persist=nc)
        log.info("plan", f"[serve_lm] resident: full {s_kv}-token cache in HBM",
                 plan="resident", s_kv=s_kv)

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(
        cfg, plan, mesh, shape, params, paging=paging,
        admission=None if args.admission == "auto" else args.admission,
        prefill_chunk=args.prefill_chunk or None)

    dev_args = None
    if args.compiled_memory:
        # measured per-device memory of the compiled step (args hold the
        # cache); a second compile, so opt-in — CI runs without it
        mem = engine.art.lower(donate=False).compile().memory_analysis()
        dev_args = mem.argument_size_in_bytes

    engine.submit(build_requests(args.requests, cfg.vocab_size, args.max_new))
    report = engine.run()
    tok_s = report.generated_tokens / max(report.wall_s, 1e-9)
    log.info("served",
             f"[serve_lm] served {len(report.finished)} requests, "
             f"{report.generated_tokens} tokens in {report.steps} steps "
             f"({report.prefill_ticks} prefill / {report.decode_ticks} decode, "
             f"admission={report.admission}"
             + (f", chunk={report.prefill_chunk}" if report.prefill_chunk else "")
             + f"; {tok_s:.1f} tok/s, evictions={report.evictions}"
             + ("" if report.drained else f", STOPPED with pending={report.pending}")
             + ")",
             **report.to_dict())
    log.info("latency",
             f"[serve_lm] latency p50/p99 {report.p50_latency_s:.4f}/"
             f"{report.p99_latency_s:.4f}s, TTFT p50/p99 {report.p50_ttft_s:.4f}/"
             f"{report.p99_ttft_s:.4f}s, p99 ITL {report.p99_itl_s:.4f}s")
    for rid in sorted(report.finished):
        print(f"  req {rid}: {report.finished[rid]}")
    hbm_dev = report.hbm_cache_bytes / n_dev
    res_dev = report.resident_cache_bytes / n_dev
    log.info("memory",
             f"[serve_lm] per-device HBM cache: {hbm_dev / 1e6:.3f} MB "
             f"(resident layout: {res_dev / 1e6:.3f} MB) "
             f"-> reduction x{report.hbm_reduction:.2f}; "
             f"host pages: {report.host_cache_bytes / n_dev / 1e6:.3f} MB/device",
             hbm_dev_bytes=int(hbm_dev), resident_dev_bytes=int(res_dev),
             hbm_reduction=round(report.hbm_reduction, 2))
    if dev_args is not None:
        log.info("compiled_memory",
                 f"[serve_lm] compiled per-device argument bytes: "
                 f"{dev_args / 1e6:.3f} MB", argument_bytes=int(dev_args))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
