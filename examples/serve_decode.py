"""Streaming serving demo: submit a prompt batch to the decode engine and
consume tokens as ``TokenEvent``s while requests are still in flight.

    PYTHONPATH=src python examples/serve_decode.py

Prompts enter the cache through the chunked-prefill program (one compiled
``lax.scan`` of decode steps per chunk — see docs/serving.md §5) interleaved
with decode ticks, so the first request starts streaming before the last
prompt has finished ingesting. Compare examples/serve_lm.py, which drives
``run()`` to completion and reports aggregate latency percentiles.
"""
import time

import jax

from repro.compat import ensure_jax_compat

ensure_jax_compat()

from repro.configs import get_config, reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core.plan import MemoryPlan  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve import DecodeEngine, Request  # noqa: E402

B, PROMPT, GEN = 4, 32, 16

cfg = reduced(get_config("mixtral-8x22b"))
mesh = make_local_mesh()
shape = ShapeConfig("serve", PROMPT + GEN, B, "decode")
plan = MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4)

params = M.init_params(cfg, jax.random.PRNGKey(0))
engine = DecodeEngine(cfg, plan, mesh, shape, params)

prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 1, cfg.vocab_size)
engine.submit([Request(i, [int(t) for t in prompts[i]], GEN) for i in range(B)])

t0 = time.time()
streams: dict[int, list[int]] = {}
for ev in engine.stream():
    streams.setdefault(ev.rid, []).append(ev.token)
    if ev.finished:
        print(f"req {ev.rid} finished at +{time.time() - t0:.2f}s "
              f"({len(streams[ev.rid])} tokens)")

report = engine.report()
dt = max(report.wall_s, 1e-9)
print(f"decoded {report.generated_tokens} tokens x {B} seqs in {dt:.2f}s "
      f"({report.generated_tokens / dt:.1f} tok/s on CPU; "
      f"{report.prefill_ticks} prefill chunks of {report.prefill_chunk}, "
      f"{report.decode_ticks} decode ticks)")
print("sample token ids:", streams[0][:16])
