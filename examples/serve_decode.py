"""Batched serving demo: prefill a prompt batch, then decode with the
plan-sharded KV cache — the serve-side of the framework.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.plan import MemoryPlan
from repro.launch.mesh import make_local_mesh
from repro.models import kvcache as KV
from repro.models import model as M
from repro.train.step_builder import build_decode_step

cfg = reduced(get_config("mixtral-8x22b"))
B, PROMPT, GEN = 4, 32, 32
mesh = make_local_mesh()
plan = MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4)
shape = ShapeConfig("serve", PROMPT + GEN, B, "decode")

params = M.init_params(cfg, jax.random.PRNGKey(0))
# serving layout: canonical stacked blocks (same tree the decode step expects)
art = build_decode_step(cfg, plan, mesh, shape)
step = jax.jit(art.fn, donate_argnums=(0,))

prompt = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)
cache = KV.init_cache(cfg, B, PROMPT + GEN)
state = {"params": params, "cache": cache}

# prefill = teacher-forced decode over the prompt (simple and correct; a
# production server would use build_prefill_step to batch this)
t0 = time.time()
tok = prompt[:, :1]
for t in range(PROMPT):
    state, nxt = step(state, {"tokens": prompt[:, t:t + 1], "pos": jnp.int32(t)})
print(f"prefill {PROMPT} tokens x {B} seqs: {time.time()-t0:.2f}s")

t0 = time.time()
generated = [nxt[:, None]]
tok = nxt[:, None]
for t in range(PROMPT, PROMPT + GEN - 1):
    state, nxt = step(state, {"tokens": tok, "pos": jnp.int32(t)})
    tok = nxt[:, None]
    generated.append(tok)
out = jnp.concatenate(generated, axis=1)
dt = time.time() - t0
print(f"decoded {GEN} tokens x {B} seqs in {dt:.2f}s "
      f"({B * GEN / dt:.1f} tok/s on CPU interpret)")
print("sample token ids:", out[0, :16].tolist())
