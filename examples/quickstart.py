"""Quickstart: ProTrain-style automatic memory management in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. pick an architecture config,
2. let the planner search {n_persist, n_buffer, n_host, n_swap, n_checkpoint}
   for the target hardware,
3. build the plan-realized train step and run a few steps.
"""
import jax

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import TPU_V5E, SINGLE_POD, build_workload, search
from repro.core.plan import fully_resident_plan
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.train.step_builder import build_train_step

# --- 1. the model ----------------------------------------------------------
cfg = get_config("llama3-405b")

# --- 2. what would ProTrain do on a real v5e pod? ---------------------------
shape = ShapeConfig("train", seq_len=4096, global_batch=256, mode="train")
workload = build_workload(cfg, shape, SINGLE_POD, TPU_V5E)
result = search(workload, sp="auto")
print(f"405B plan on 256 x v5e : {result.plan.describe()}")
print(f"  modeled step time    : {result.runtime.t_iteration:.2f}s "
      f"({result.runtime.tokens_per_second:,.0f} tok/s)")
print(f"  modeled peak memory  : {result.memory.peak/1e9:.2f} GB / {TPU_V5E.hbm_bytes/1e9:.0f} GB HBM")
print(f"  search               : {result.evaluated} cells in {result.search_seconds*1e3:.0f} ms")

# --- 3. actually train the reduced variant locally --------------------------
tiny = reduced(cfg)
local_shape = ShapeConfig("local", seq_len=128, global_batch=4, mode="train")
mesh = make_local_mesh()
plan = fully_resident_plan(n_chunks=4, n_blocks=2)  # tiny model: keep it simple
art = build_train_step(tiny, plan, mesh, local_shape)
state = art.init(jax.random.PRNGKey(0))
pipe = SyntheticTokenPipeline(tiny, local_shape, seed=0)
step = jax.jit(art.fn, donate_argnums=(0,))
for i in range(10):
    state, metrics = step(state, pipe.next_sync())
    print(f"step {i}: loss={float(metrics['loss']):.4f}")
