"""ProTrain's automatic memory management across models and hardware —
reproduces the shape of the paper's Table 4 analysis: how the searched
configuration responds to batch size, hardware, and model size.

    PYTHONPATH=src python examples/autotune_demo.py
"""
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.configs.paper_models import PAPER_MODELS
from repro.core import build_workload, search
from repro.core.hardware import A100_80G, RTX_3090, TPU_V5E, MeshSpec, SINGLE_POD

GPU4 = MeshSpec((4,), ("data",))

print(f"{'model':12s} {'batch':>5s} {'hardware':10s} | {'searched configuration':50s} | modeled tok/s")
print("-" * 110)
rows = [
    ("gpt2-1b", 8, RTX_3090), ("gpt2-1b", 64, RTX_3090), ("gpt2-1b", 64, A100_80G),
    ("gpt2-10b", 8, RTX_3090), ("gpt2-10b", 8, A100_80G),
    ("mistral-7b", 64, A100_80G), ("llama-13b", 64, A100_80G),
]
for name, batch, hw in rows:
    cfg = PAPER_MODELS[name]
    shape = ShapeConfig("paper", 1024, batch, "train")
    w = build_workload(cfg, shape, GPU4, hw)
    res = search(w)
    print(f"{name:12s} {batch:5d} {hw.name:10s} | {res.plan.describe():50s} | "
          f"{res.runtime.tokens_per_second:>10,.0f}")

print()
print("TPU v5e pod (256 chips), assigned architectures:")
for arch in ("llama3-405b", "mixtral-8x22b", "jamba-1.5-large-398b", "mamba2-130m"):
    cfg = get_config(arch)
    shape = ShapeConfig("train_4k", 4096, 256, "train")
    w = build_workload(cfg, shape, SINGLE_POD, TPU_V5E)
    res = search(w, sp="auto")
    print(f"{arch:22s} | {res.plan.describe():55s} | {res.runtime.tokens_per_second:>10,.0f} tok/s"
          f" | feasible={res.feasible}")
