"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
full production stack — planner, plan-realized step, data pipeline,
fault-tolerant loop with checkpoint/auto-resume.

    PYTHONPATH=src python examples/train_lm.py                 # ~25M, fast
    PYTHONPATH=src python examples/train_lm.py --full          # mamba2-130m
    PYTHONPATH=src python examples/train_lm.py --resume-demo   # kill + resume

The --resume-demo flag trains, simulates a crash halfway, then restarts from
the latest checkpoint and verifies the loss continues from where it left off.
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.plan import fully_resident_plan
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.models.model import num_repeats
from repro.core.chunks import chunk_inventory
from repro.optim.adam import AdamConfig, cosine_schedule
from repro.train.loop import LoopConfig, train_loop
from repro.train.step_builder import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="use the real mamba2-130m config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume-demo", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")
    if not args.full:
        # ~25M-param same-family variant so CPU steps stay ~1s
        cfg = dataclasses.replace(cfg, num_layers=8, d_model=512, vocab_size=8192)
    shape = ShapeConfig("train", seq_len=256, global_batch=8, mode="train")
    mesh = make_local_mesh()
    plan = fully_resident_plan(len(chunk_inventory(cfg)), num_repeats(cfg))
    art = build_train_step(
        cfg, plan, mesh, shape,
        adam=AdamConfig(lr=1e-3),
        lr_schedule=cosine_schedule(1e-3, warmup=20, total=args.steps),
    )
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, plan={plan.describe()}")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    if args.resume_demo:
        half = args.steps // 2
        pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
        r1 = train_loop(art, pipe, mgr, LoopConfig(total_steps=half, checkpoint_every=25,
                                                   log_every=25))
        print(f"[train_lm] 'crash' after {r1.final_step} steps "
              f"(loss {r1.losses[0]:.3f} -> {r1.losses[-1]:.3f}); restarting...")
        pipe2 = SyntheticTokenPipeline(cfg, shape, seed=0)
        r2 = train_loop(art, pipe2, mgr, LoopConfig(total_steps=args.steps,
                                                    checkpoint_every=50, log_every=25))
        assert r2.resumed_from is not None, "resume failed"
        print(f"[train_lm] resumed from step {r2.resumed_from}, "
              f"final loss {r2.losses[-1]:.3f} (continued below {r1.losses[-1]:.3f})")
    else:
        pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
        res = train_loop(art, pipe, mgr, LoopConfig(total_steps=args.steps,
                                                    checkpoint_every=100, log_every=20))
        print(f"[train_lm] done: loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
              f"over {res.steps_run} steps")


if __name__ == "__main__":
    main()
