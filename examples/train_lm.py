"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
full production stack — planner, plan-realized step, data pipeline,
fault-tolerant loop with checkpoint/auto-resume.

    PYTHONPATH=src python examples/train_lm.py                 # ~25M, fast
    PYTHONPATH=src python examples/train_lm.py --full          # mamba2-130m
    PYTHONPATH=src python examples/train_lm.py --resume-demo   # kill + resume
    PYTHONPATH=src python examples/train_lm.py --plan zero3    # manual ZeRO-3

The --resume-demo flag trains, simulates a crash halfway, then restarts from
the latest checkpoint and verifies the loss continues from where it left off.
--plan zero2/zero3 shards the model states (manual compressed sync by
default; the printed plan summary shows the ZeRO-3 lazy-gather memory win
over the up-front-gather zero2 layout). --overlap on|off toggles the manual
path's comm/compute overlap (ISSUE-7); the summary prints the serial-vs-
overlapped modeled step time either way. On a 1-device host the manual plans
fall back to the numerics-identical local-math path.
"""
import argparse
import dataclasses
import shutil

import jax

from repro import obs
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.plan import MemoryPlan, fully_resident_plan
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.models.model import num_repeats
from repro.core.chunks import chunk_inventory
from repro.optim.adam import AdamConfig, cosine_schedule
from repro.train.loop import LoopConfig, train_loop
from repro.train.step_builder import build_train_step


def make_plan(args, nc: int, nb: int) -> MemoryPlan:
    if args.plan == "resident":
        plan = fully_resident_plan(nc, nb)
        if args.sync_mode != "xla" or args.compress != "none":
            plan = dataclasses.replace(
                plan, sync_mode=args.sync_mode, grad_compress=args.compress)
    else:
        # ZeRO-sharded: manual compressed sync is the point of these plans
        plan = MemoryPlan(
            nc, nb, n_persist=0, n_buffer=args.n_buffer,
            zero_stage=3 if args.plan == "zero3" else 2,
            sync_mode=args.sync_mode, grad_compress=args.compress,
        )
    if args.overlap == "off":
        plan = dataclasses.replace(plan, overlap=False)
    return plan


def plan_summary(cfg, shape, mesh, plan) -> str:
    """Printed plan line: describe() + manual kind + estimated per-device
    peak (and the zero3-vs-zero2 delta, the ISSUE-4 memory win)."""
    from repro.core import build_workload, estimate_memory
    from repro.core.hardware import LOCAL_CPU_HW, MeshSpec

    w = build_workload(cfg, shape, MeshSpec(
        tuple(mesh.devices.shape), tuple(mesh.axis_names)), LOCAL_CPU_HW)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    kind = plan.manual_sync_kind(tp) if plan.sync_mode == "manual" else None
    est = estimate_memory(w, plan)
    line = (f"plan={plan.describe()} kind={kind or 'xla'} "
            f"est_peak={est.peak / 1e9:.3f}GB")
    if kind == "zero3":
        est2 = estimate_memory(w, dataclasses.replace(plan, zero_stage=2))
        line += (f" (zero2 would be {est2.peak / 1e9:.3f}GB: lazy per-chunk "
                 f"gather saves {(est2.peak - est.peak) / 1e6:.0f}MB "
                 f"gathered-params + grad-workspace)")
    if kind is not None:
        # ISSUE-7: the overlap knob changes the schedule, so show both
        # pricings — the hidden-comm delta is the reason --overlap exists
        from repro.core import estimate_runtime

        t_here = estimate_runtime(w, plan).t_iteration
        t_twin = estimate_runtime(
            w, dataclasses.replace(plan, overlap=not plan.overlap)).t_iteration
        t_ov, t_ser = ((t_here, t_twin) if plan.overlap else (t_twin, t_here))
        line += (f" modeled_t_iter={t_here * 1e3:.2f}ms [overlap="
                 f"{'on' if plan.overlap else 'off'}: overlapped "
                 f"{t_ov * 1e3:.2f}ms vs serial {t_ser * 1e3:.2f}ms, comm "
                 f"hidden {(1 - t_ov / max(t_ser, 1e-12)) * 100:.1f}%]")
    return line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="use the real mamba2-130m config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--resume-demo", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--plan", choices=["resident", "zero2", "zero3"],
                    default="resident",
                    help="resident: everything replicated; zero2/zero3: "
                         "ZeRO-sharded states with manual compressed sync "
                         "(zero3 = lazy per-chunk gather)")
    ap.add_argument("--sync-mode", choices=["xla", "manual"], default=None,
                    help="gradient-reduce ownership (default: manual for "
                         "zero2/zero3 plans, xla for resident)")
    ap.add_argument("--compress", choices=["none", "bf16", "int8_ef"],
                    default=None,
                    help="gradient wire compression (default: int8_ef for "
                         "manual plans, none for xla)")
    ap.add_argument("--overlap", choices=["on", "off"], default="on",
                    help="manual-path comm/compute overlap (double-buffered "
                         "gather prefetch + deferred-accumulation reduce-"
                         "scatter); off builds and prices the serial "
                         "schedule — the printed summary shows both modeled "
                         "step times either way")
    ap.add_argument("--log-jsonl", default=None, metavar="PATH",
                    help="also append every log line as a structured JSONL "
                         "record (obs.StructuredLogger)")
    args = ap.parse_args()
    log = obs.StructuredLogger("train_lm", jsonl_path=args.log_jsonl)
    if args.sync_mode is None:
        args.sync_mode = "xla" if args.plan == "resident" else "manual"
    if args.compress is None:
        args.compress = "int8_ef" if args.sync_mode == "manual" else "none"
    args.n_buffer = 0

    cfg = get_config("mamba2-130m")
    if not args.full:
        # ~25M-param same-family variant so CPU steps stay ~1s
        cfg = dataclasses.replace(cfg, num_layers=8, d_model=512, vocab_size=8192)
    shape = ShapeConfig("train", seq_len=256, global_batch=8, mode="train")
    # manual ZeRO needs tp == 1: fold every local device onto the data axis
    n_dev = len(jax.devices())
    mesh = (make_local_mesh() if args.plan == "resident"
            else jax.make_mesh((n_dev, 1), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,) * 2))
    plan = make_plan(args, len(chunk_inventory(cfg)), num_repeats(cfg))
    art = build_train_step(
        cfg, plan, mesh, shape,
        adam=AdamConfig(lr=1e-3),
        lr_schedule=cosine_schedule(1e-3, warmup=20, total=args.steps),
    )
    log.info("plan",
             f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
             + plan_summary(cfg, shape, mesh, plan),
             arch=cfg.name, params_m=round(cfg.param_count() / 1e6, 1),
             plan=plan.describe())

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    if args.resume_demo:
        half = args.steps // 2
        pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
        r1 = train_loop(art, pipe, mgr, LoopConfig(total_steps=half, checkpoint_every=25,
                                                   log_every=25), log=log)
        log.info("crash",
                 f"[train_lm] 'crash' after {r1.final_step} steps "
                 f"(loss {r1.losses[0]:.3f} -> {r1.losses[-1]:.3f}); restarting...",
                 step=r1.final_step, loss=round(float(r1.losses[-1]), 3))
        pipe2 = SyntheticTokenPipeline(cfg, shape, seed=0)
        r2 = train_loop(art, pipe2, mgr, LoopConfig(total_steps=args.steps,
                                                    checkpoint_every=50, log_every=25),
                        log=log)
        assert r2.resumed_from is not None, "resume failed"
        log.info("resumed",
                 f"[train_lm] resumed from step {r2.resumed_from}, "
                 f"final loss {r2.losses[-1]:.3f} (continued below {r1.losses[-1]:.3f})",
                 resumed_from=r2.resumed_from,
                 loss=round(float(r2.losses[-1]), 3))
    else:
        pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
        res = train_loop(art, pipe, mgr, LoopConfig(total_steps=args.steps,
                                                    checkpoint_every=100, log_every=20),
                        log=log)
        log.info("done",
                 f"[train_lm] done: loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
                 f"over {res.steps_run} steps",
                 steps=res.steps_run, loss=round(float(res.losses[-1]), 3))


if __name__ == "__main__":
    main()
