"""Kernel bench: the fused Pallas kernels against their lax baselines.

Emits one row per kernel with *modeled* HBM byte counts on both sides and
*measured* wall times (informational on this CPU container — interpret mode
executes the kernel body op-by-op through the Pallas interpreter, so its
wall clock measures the interpreter, not the kernel; on a real accelerator
the measured column becomes the contract). The CI gate is the modeled
contrast: the kernel's byte inventory — taken from the traced pallas_call
block census, i.e. what the kernel *actually* streams per grid step — must
be strictly below the lax pipeline's pass count at the cost model's
pricing, or the perf claim the planner acts on
(cost_model.KERNEL_CACHE_PASSES < LAX_REBUILD_CACHE_PASSES, 1 fused
quantize pass < 3 unfused) has rotted.

Rows:
  * paged_attention — fused decode attention over the paged cache layout
    (kernels/paged_attention.py) vs the lax gather-then-attend rebuild
    (serve/paging.PagedKV.update_and_fetch + _masked_decode_attn): 2 cache
    passes vs 3.
  * fused_quant — one-pass int8 absmax quantize+pack+EF-residual
    (kernels/fused_quant.py) vs the three-op sequence in
    dist/collectives.manual_int8_ef_reduce_scatter.

Usage:
    PYTHONPATH=src python benchmarks/kernel_bench.py [--out BENCH_kernels.json]
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # must precede jax import; mirror CI
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from calibrate_wire import _pallas_block_census  # noqa: E402

from repro.core.cost_model import (  # noqa: E402
    KERNEL_CACHE_PASSES,
    LAX_REBUILD_CACHE_PASSES,
)
from repro.kernels import ref as R  # noqa: E402
from repro.kernels.fused_quant import fused_quantize_ef  # noqa: E402
from repro.kernels.paged_attention import paged_attention  # noqa: E402


def _time_ms(fn, *args, iters: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def bench_paged_attention(*, b: int = 4, hq: int = 8, hkv: int = 2,
                          s_kv: int = 256, page_size: int = 16,
                          n_hot: int = 2, hd: int = 64) -> dict:
    w = n_hot * page_size
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 7)
    f32 = jnp.float32
    args = (jax.random.normal(ks[0], (b, 1, hq, hd), f32),
            jax.random.normal(ks[1], (b, w, hkv, hd), f32),
            jax.random.normal(ks[2], (b, w, hkv, hd), f32),
            jax.random.normal(ks[3], (b, s_kv, hkv, hd), f32),
            jax.random.normal(ks[4], (b, s_kv, hkv, hd), f32),
            jax.random.bernoulli(ks[5], 0.5, (b, s_kv)),
            jnp.where(jax.random.bernoulli(ks[6], 0.9, (b, s_kv)),
                      0.0, -1e30).astype(f32))
    kern = functools.partial(paged_attention, n_hot=n_hot, interpret=True)
    lax_ref = jax.jit(R.paged_attention_ref)
    kv_bytes = 2 * b * s_kv * hkv * hd * 4  # k + v cache working set, fp32
    census = _pallas_block_census(lambda *a: kern(*a), *args)
    kv_stream = [r for r in census["inputs"]
                 if r["block_shape"] == (1, page_size, hd)]
    modeled_kernel = census["grid_steps"] * sum(
        r["bytes_per_step"] for r in kv_stream)
    assert modeled_kernel == KERNEL_CACHE_PASSES * kv_bytes, (
        "block census no longer matches the cost model's kernel pass count")
    return {
        "kernel": "paged_attention",
        "shape": {"b": b, "hq": hq, "hkv": hkv, "s_kv": s_kv,
                  "page_size": page_size, "n_hot": n_hot, "hd": hd},
        "modeled_kernel_bytes": int(modeled_kernel),
        "modeled_lax_bytes": int(LAX_REBUILD_CACHE_PASSES * kv_bytes),
        "speedup_modeled": round(
            LAX_REBUILD_CACHE_PASSES * kv_bytes / modeled_kernel, 4),
        "measured_kernel_ms": round(_time_ms(kern, *args), 3),
        "measured_lax_ms": round(_time_ms(lax_ref, *args), 3),
        "measured_is_interpret_mode": True,
    }


def bench_fused_quant(*, z: int = 4, n: int = 1 << 18) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    ch = (jax.random.normal(ks[0], (z, n), jnp.float32)
          * jnp.exp(jax.random.normal(ks[1], (z, 1))))
    kern = functools.partial(fused_quantize_ef, interpret=True)
    lax_ref = jax.jit(R.fused_quantize_ef_ref)
    work = z * n * 4  # fp32 chunk working set
    census = _pallas_block_census(lambda c, m: kern(c, m), ch, jnp.int32(0))
    ch_stream = [r for r in census["inputs"] if r["block_shape"] == (1, n)]
    modeled_kernel = census["grid_steps"] * sum(
        r["bytes_per_step"] for r in ch_stream)
    assert modeled_kernel == work, (
        "fused-quant census no longer reads the chunk exactly once")
    return {
        "kernel": "fused_quant",
        "shape": {"z": z, "n": n},
        "modeled_kernel_bytes": int(modeled_kernel),
        "modeled_lax_bytes": int(3 * work),  # absmax + quantize + residual
        "speedup_modeled": round(3 * work / modeled_kernel, 4),
        "measured_kernel_ms": round(_time_ms(kern, ch, jnp.int32(0)), 3),
        "measured_lax_ms": round(_time_ms(lax_ref, ch, jnp.int32(0)), 3),
        "measured_is_interpret_mode": True,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()

    rows = [bench_paged_attention(), bench_fused_quant()]
    doc = {"generated_by": "benchmarks/kernel_bench.py",
           "backend": jax.default_backend(), "kernels": rows}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    ok = True
    for r in rows:
        faster = r["modeled_kernel_bytes"] < r["modeled_lax_bytes"]
        ok &= faster
        print(f"[kernel_bench] {r['kernel']}: modeled {r['modeled_kernel_bytes']}"
              f" vs lax {r['modeled_lax_bytes']} bytes "
              f"(x{r['speedup_modeled']}), measured {r['measured_kernel_ms']}ms"
              f" vs {r['measured_lax_ms']}ms (interpret) "
              f"{'OK' if faster else 'FAIL'}")
    print(f"[kernel_bench] wrote {args.out}")
    if not ok:
        print("[kernel_bench] FAIL: a kernel is not strictly cheaper than its"
              " lax baseline in modeled bytes — the planner's kernel-aware"
              " pricing (cost_model) is now claiming a speedup that the block"
              " census does not support")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
