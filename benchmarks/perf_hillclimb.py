"""§Perf hillclimbing driver: run named plan variants for the three chosen
cells, recompile, and record the roofline deltas.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --cell stablelm --iter dp_only

Appends to reports/hillclimb.jsonl. The hypothesis -> change -> before ->
after log lives in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

CELLS = {
    "stablelm": ("stablelm-3b", "train_4k"),
    "jamba": ("jamba-1.5-large-398b", "train_4k"),
    "llama": ("llama3-405b", "train_4k"),
}


def get_plan(arch, shape_name, variant: str):
    from repro.configs import get_config, get_shape
    from repro.core import TPU_V5E, SINGLE_POD, build_workload, search

    cfg = get_config(arch)
    w = build_workload(cfg, get_shape(shape_name), SINGLE_POD, TPU_V5E)
    if variant == "baseline":
        return search(w, sp="off", dp="off")
    if variant == "sp":
        return search(w, sp="on", dp="off")
    if variant == "sp_auto":
        return search(w, sp="auto", dp="off")
    if variant == "dp_only":
        return search(w, sp="off", dp="on")
    if variant == "full_auto":
        return search(w, sp="auto", dp="auto")
    if variant == "best":
        # accepted move set: SP excluded — measured HLO showed XLA's SPMD
        # resolves the SP double-sharding by replicating weights over TP
        # (see EXPERIMENTS.md §Perf, refuted iteration)
        return search(w, sp="off", dp="auto")
    if variant == "zero1":
        res = search(w, sp="auto", dp="auto")
        plan = dataclasses.replace(res.plan, zero1_persistent=True)
        res.plan = plan
        return res
    raise KeyError(variant)


def run(cell: str, variant: str, out_path: str | None):
    from repro import obs
    from repro.launch.dryrun import run_cell

    arch, shape = CELLS[cell]
    res = get_plan(arch, shape, variant)
    # run_cell's lower_s/compile_s come from the same obs spans this wraps,
    # so an installed tracer sees the variant end to end (one clock)
    with obs.current_telemetry().tracer.span(
            "hillclimb.variant", cell=cell, variant=variant):
        rec = run_cell(arch, shape, False, sp=variant, plan_override=res.plan)
    rec["variant"] = variant
    rec["modeled_t_iter"] = res.runtime.t_iteration
    rec["modeled_feasible"] = res.feasible
    if out_path is not None:
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    rl = rec["roofline"]
    print(f"[hillclimb] {cell}/{variant}: plan={rec['plan']}")
    print(f"  comp={rl['t_compute_s']:.3f}s mem={rl['t_memory_s']:.3f}s "
          f"coll={rl['t_collective_s']:.3f}s bottleneck={rl['bottleneck']} "
          f"useful={rl['useful_flops_ratio']:.2f} modeled_t={res.runtime.t_iteration:.2f}s")
    return rec


def overlap_bench(cell: str) -> dict:
    """ISSUE-7 acceptance row: the overlapped manual zero3 schedule's
    modeled step time vs the serial (``overlap=False``) schedule on the
    *same* plan and workload — the pre-overlap baseline every earlier
    BENCH_train.json priced. Manual sync needs tp == 1, so the cell is
    evaluated on the pod folded to pure DP (the same fold the autotuner's
    dp_only candidates use)."""
    from repro.configs import get_config, get_shape
    from repro.core import TPU_V5E, SINGLE_POD, build_workload, estimate_runtime
    from repro.core.hardware import MeshSpec
    from repro.core.plan import MemoryPlan

    arch, shape = CELLS[cell]
    cfg = get_config(arch)
    dp = MeshSpec((SINGLE_POD.n_chips,), ("data",))
    w = build_workload(cfg, get_shape(shape), dp, TPU_V5E)
    plan = MemoryPlan(w.n_chunks, w.n_blocks, n_buffer=w.n_chunks,
                      grad_compress="int8_ef", sync_mode="manual", zero_stage=3)
    t_ov = estimate_runtime(w, plan).t_iteration
    t_ser = estimate_runtime(
        w, dataclasses.replace(plan, overlap=False)).t_iteration
    return {
        "plan": plan.describe(),
        "overlap_t_iter": t_ov,
        "serial_t_iter": t_ser,
        "overlap_speedup": t_ser / max(t_ov, 1e-12),
    }


def adaptive_policy_bench(cell: str) -> dict:
    """ISSUE-9 acceptance row: the per-block activation-policy search
    (keep / remat / compress8 per block) against the two uniform policies on
    the same workload, at a budget chosen so keep-all is infeasible — the
    regime the adaptive policy exists for. The budget is bracketed between
    the remat-all and keep-all modeled peaks (midpoint), so remat-all is the
    best *uniform* fallback; the searched vector must fit the budget and
    model a strictly lower step time (compress8 trades a half-recompute +
    two HBM passes for remat's full recompute, block by block). Raises on
    violation so the CI artifact job goes red, not quietly stale."""
    import dataclasses

    from repro.configs import get_config, get_shape
    from repro.core import (
        TPU_V5E, SINGLE_POD, build_workload, estimate_memory, estimate_runtime,
    )
    from repro.core.autotuner import search_act_policies
    from repro.core.plan import MemoryPlan

    arch, shape = CELLS[cell]
    cfg = get_config(arch)
    w = build_workload(cfg, get_shape(shape), SINGLE_POD, TPU_V5E)
    keep = MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks)
    remat = dataclasses.replace(keep, n_checkpoint=w.n_blocks)
    mem_keep = estimate_memory(w, keep).peak
    mem_remat = estimate_memory(w, remat).peak
    budget = 0.5 * (mem_keep + mem_remat)
    assert mem_remat < budget < mem_keep, "cell no longer brackets the budget"

    res = search_act_policies(w, keep, capacity_bytes=budget)
    mem_adapt = estimate_memory(w, res.plan).peak
    t_adapt = res.runtime.t_iteration
    t_remat = estimate_runtime(w, remat).t_iteration
    t_keep = estimate_runtime(w, keep).t_iteration
    row = {
        "budget_gb": round(budget / 1e9, 3),
        "keep_all": {"peak_gb": round(mem_keep / 1e9, 3),
                     "t_iter": t_keep, "feasible": False},
        "remat_all": {"peak_gb": round(mem_remat / 1e9, 3),
                      "t_iter": t_remat, "feasible": True},
        "adaptive": {"peak_gb": round(mem_adapt / 1e9, 3),
                     "t_iter": t_adapt, "feasible": res.feasible,
                     "plan": res.plan.describe()},
        "speedup_vs_remat_all": t_remat / max(t_adapt, 1e-12),
    }
    if not (res.feasible and mem_adapt < budget):
        raise RuntimeError(f"adaptive policy search missed the budget: {row}")
    if t_adapt >= t_remat:
        raise RuntimeError(
            "adaptive activation policy no longer beats the best uniform "
            f"policy (remat-all) at equal budget: {row}")
    return row


def bench_out(path: str, cell: str = "stablelm"):
    """CI artifact mode: recompile the cell's excluded-move baseline and
    accepted-best plans and emit ``BENCH_train.json`` — roofline terms,
    XLA buffer assignment, and modeled iteration time per variant, plus the
    modeled speedup, and the overlapped-vs-serial manual zero3 comparison
    (ISSUE-7). Plan search and roofline are deterministic; the
    lower/compile wall-time fields jitter run to run."""
    arch, shape = CELLS[cell]
    variants = {v: run(cell, v, None) for v in ("baseline", "best")}
    bench = {
        "bench": "train_hillclimb",
        "cell": cell,
        "arch": arch,
        "shape": shape,
        "variants": variants,
        "modeled_speedup": (variants["baseline"]["modeled_t_iter"]
                            / max(variants["best"]["modeled_t_iter"], 1e-12)),
        "zero3_overlap": overlap_bench(cell),
        "adaptive_act_policy": adaptive_policy_bench(cell),
    }
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    ov = bench["zero3_overlap"]
    ap_ = bench["adaptive_act_policy"]
    print(f"[hillclimb] wrote {path} "
          f"(modeled speedup x{bench['modeled_speedup']:.3f}, "
          f"zero3 overlap x{ov['overlap_speedup']:.3f} vs serial, "
          f"adaptive acts x{ap_['speedup_vs_remat_all']:.3f} vs remat-all)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS))
    ap.add_argument("--iter")
    ap.add_argument("--out", default="reports/hillclimb.jsonl")
    ap.add_argument("--bench-out", metavar="PATH",
                    help="emit a baseline-vs-best BENCH_train.json for the "
                         "--cell (default stablelm) instead of appending a "
                         "single hillclimb iteration")
    args = ap.parse_args()
    if args.bench_out:
        bench_out(args.bench_out, cell=args.cell or "stablelm")
        return
    if not args.cell or not args.iter:
        ap.error("--cell and --iter are required without --bench-out")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    run(args.cell, args.iter, args.out)


if __name__ == "__main__":
    main()
