"""§Perf hillclimbing driver: run named plan variants for the three chosen
cells, recompile, and record the roofline deltas.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb --cell stablelm --iter dp_only

Appends to reports/hillclimb.jsonl. The hypothesis -> change -> before ->
after log lives in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

CELLS = {
    "stablelm": ("stablelm-3b", "train_4k"),
    "jamba": ("jamba-1.5-large-398b", "train_4k"),
    "llama": ("llama3-405b", "train_4k"),
}


def get_plan(arch, shape_name, variant: str):
    from repro.configs import get_config, get_shape
    from repro.core import TPU_V5E, SINGLE_POD, build_workload, search

    cfg = get_config(arch)
    w = build_workload(cfg, get_shape(shape_name), SINGLE_POD, TPU_V5E)
    if variant == "baseline":
        return search(w, sp="off", dp="off")
    if variant == "sp":
        return search(w, sp="on", dp="off")
    if variant == "sp_auto":
        return search(w, sp="auto", dp="off")
    if variant == "dp_only":
        return search(w, sp="off", dp="on")
    if variant == "full_auto":
        return search(w, sp="auto", dp="auto")
    if variant == "best":
        # accepted move set: SP excluded — measured HLO showed XLA's SPMD
        # resolves the SP double-sharding by replicating weights over TP
        # (see EXPERIMENTS.md §Perf, refuted iteration)
        return search(w, sp="off", dp="auto")
    if variant == "zero1":
        res = search(w, sp="auto", dp="auto")
        plan = dataclasses.replace(res.plan, zero1_persistent=True)
        res.plan = plan
        return res
    raise KeyError(variant)


def run(cell: str, variant: str, out_path: str):
    from repro.launch.dryrun import run_cell

    arch, shape = CELLS[cell]
    res = get_plan(arch, shape, variant)
    rec = run_cell(arch, shape, False, sp=variant, plan_override=res.plan)
    rec["variant"] = variant
    rec["modeled_t_iter"] = res.runtime.t_iteration
    rec["modeled_feasible"] = res.feasible
    with open(out_path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    rl = rec["roofline"]
    print(f"[hillclimb] {cell}/{variant}: plan={rec['plan']}")
    print(f"  comp={rl['t_compute_s']:.3f}s mem={rl['t_memory_s']:.3f}s "
          f"coll={rl['t_collective_s']:.3f}s bottleneck={rl['bottleneck']} "
          f"useful={rl['useful_flops_ratio']:.2f} modeled_t={res.runtime.t_iteration:.2f}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--iter", required=True)
    ap.add_argument("--out", default="reports/hillclimb.jsonl")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    run(args.cell, args.iter, args.out)


if __name__ == "__main__":
    main()
