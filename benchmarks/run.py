"""Benchmark harness: one section per paper table/figure + the TPU roofline.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only table2,fig5

Prints ``name,value,...`` CSV rows per section (machine-parsable) plus the
roofline markdown table sourced from reports/dryrun_cells.jsonl.
"""
from __future__ import annotations

import argparse
import sys
import time


def _csv(section: str, rows: list[dict]) -> None:
    if not rows:
        print(f"{section},EMPTY")
        return
    cols = list(rows[0].keys())
    print(f"# {section}")
    print(",".join(["section"] + cols))
    for r in rows:
        print(",".join([section] + [str(r.get(c, "")) for c in cols]))
    print()


def bench_table2():
    from benchmarks.paper_tables import table2

    _csv("table2_max_trainable_B", table2())


def bench_fig3():
    from benchmarks.paper_tables import fig3_throughput

    _csv("fig3_throughput_tokens_per_s", fig3_throughput())


def bench_fig5():
    from benchmarks.paper_tables import fig5_ablation

    _csv("fig5_ablation_slowdown_x", fig5_ablation())


def bench_table3():
    from benchmarks.paper_tables import table3_offload

    _csv("table3_offload", table3_offload())


def bench_table4():
    from benchmarks.paper_tables import table4_configs

    _csv("table4_searched_configs", table4_configs())


def bench_fig6():
    from benchmarks.estimator_fidelity import memory_fidelity, runtime_fidelity

    _csv("fig6_memory_fidelity", memory_fidelity())
    _csv("fig6_runtime_fidelity", runtime_fidelity())


def bench_search_overhead():
    """§5.3.4: profiling + search overhead."""
    from repro.configs import get_config, TRAIN_4K
    from repro.core import SINGLE_POD, TPU_V5E, build_workload, search

    rows = []
    for arch in ("mistral-7b", "gpt2-20b", "llama3-405b"):
        t0 = time.time()
        w = build_workload(get_config(arch), TRAIN_4K, SINGLE_POD, TPU_V5E)
        t_prof = time.time() - t0
        res = search(w, sp="off")
        rows.append({
            "model": arch,
            "profile_s": round(t_prof, 3),
            "search_s": round(res.search_seconds, 3),
            "evaluated": res.evaluated,
        })
    _csv("search_overhead", rows)


def bench_roofline():
    from benchmarks.roofline_table import load_cells, summary, table

    cells = load_cells()
    print("# roofline (from reports/dryrun_cells.jsonl)")
    print(table(cells))
    print(summary(cells))
    print()


def bench_kernels():
    """Microbenchmark the Pallas kernels in interpret mode vs jnp oracle
    (numbers are CPU-interpret timings — correctness artifacts, not perf)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref as R
    from repro.kernels.flash_attention import flash_attention

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(key, (1, 4, 256, 64), jnp.float32)
    v = jax.random.normal(key, (1, 4, 256, 64), jnp.float32)
    t0 = time.time()
    out = flash_attention(q, k, v, interpret=True)
    t_kernel = (time.time() - t0) * 1e6
    t0 = time.time()
    ref = R.flash_attention_ref(q, k, v)
    t_ref = (time.time() - t0) * 1e6
    err = float(jnp.abs(out - ref).max())
    _csv("kernels", [{
        "name": "flash_attention_fwd",
        "us_per_call_interpret": round(t_kernel),
        "us_per_call_ref": round(t_ref),
        "max_abs_err": err,
    }])


SECTIONS = {
    "table2": bench_table2,
    "fig3": bench_fig3,
    "fig5": bench_fig5,
    "table3": bench_table3,
    "table4": bench_table4,
    "fig6": bench_fig6,
    "search": bench_search_overhead,
    "roofline": bench_roofline,
    "kernels": bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated section names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SECTIONS)
    for name in names:
        t0 = time.time()
        try:
            SECTIONS[name]()
        except Exception as e:  # keep the harness robust: report and continue
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            print(f"{name},ERROR,{type(e).__name__}")
        print(f"# [{name} took {time.time()-t0:.1f}s]\n", file=sys.stderr)


if __name__ == "__main__":
    main()
