"""Render the §Roofline table from reports/dryrun_cells.jsonl."""
from __future__ import annotations

import json
import os

REPORT = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun_cells.jsonl")


def load_cells(path: str = REPORT, mesh: str | None = None, sp: str | None = None) -> list[dict]:
    best: dict = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if not r.get("ok"):
                continue
            if mesh and r["mesh"] != mesh:
                continue
            if sp is not None and r.get("sp", "off") != sp:
                continue
            best[(r["arch"], r["shape"], r["mesh"], r.get("sp", "off"))] = r
    return sorted(best.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def dominant_fix(r: dict) -> str:
    b = r["roofline"]["bottleneck"]
    mode = r["mode"]
    if b == "collective":
        if mode == "train":
            return "raise persist/buffer or drop TP ARs (SP / dp-only sharding)"
        return "persist weights (skip per-layer gather) / batch more requests"
    if b == "memory":
        if mode == "decode":
            return "quantize or window the KV cache; fuse cache read into attention"
        return "fuse optimizer (single HBM pass) / larger microbatches"
    return "already compute-bound: raise MXU utilization (larger tiles)"


def table(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | bottleneck | "
           "MODEL/HLO flops | what moves the dominant term |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in cells:
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['t_compute_s']:.3f} | {rl['t_memory_s']:.3f} | {rl['t_collective_s']:.3f} "
            f"| **{rl['bottleneck']}** | {rl['useful_flops_ratio']:.2f} "
            f"| {dominant_fix(r)} |"
        )
    return "\n".join(rows)


def summary(cells: list[dict]) -> dict:
    out = {"cells": len(cells), "by_bottleneck": {}}
    for r in cells:
        b = r["roofline"]["bottleneck"]
        out["by_bottleneck"][b] = out["by_bottleneck"].get(b, 0) + 1
    # roofline fraction: max-term / sum-of-terms ~ how close the dominant
    # term is to being the whole step (1.0 = perfectly overlapped elsewhere)
    return out


def main():
    cells = load_cells()
    print(table(cells))
    print()
    print(summary(cells))


if __name__ == "__main__":
    main()
