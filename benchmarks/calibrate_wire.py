"""Fit gradient-sync wire-cost factors against measured dry-run bytes.

The cost model prices the gradient reduce as

    t_reduce = grad_bytes * wire_factor(sync_mode, grad_compress) * topology / bw

where ``topology`` is the ring all-reduce term for the xla path and the
gather-based term for the manual path (see cost_model.t_reduce). This script
*measures* the collective bytes each (sync_mode, grad_compress) configuration
actually compiles to — build_train_step -> lower -> compile -> parse the HLO
with the roofline collective walker — and fits

    wire_factor = measured_wire_bytes / modeled_wire_bytes_at_factor_1

per backend, emitting a calibration JSON that ``core/cost_model.py`` loads
(``load_wire_calibration``; the packaged copy under src/repro/core/ is the
default). Two facts the fit makes honest, replacing the hand-set
GRAD_WIRE_FACTOR constant:

  * sync_mode="xla": XLA's reduce moves the *raw* gradients; the int8/bf16
    numerics are applied after, so the measured factor is ~1.0 — in-jit
    compression is accounting fiction on the wire;
  * sync_mode="manual": the int8 payload is what crosses the link (s8
    all-gathers in the HLO), so the factor reflects the real quantization
    ratio.

The manual *reduce-scatter* pipeline (ZeRO-sharded plans) is calibrated
from a zero-persist **zero3** plan: the s8 all_to_all bytes in its HLO over
the modeled scatter-topology bytes at factor 1 become the ``int8_ef_rs``
factor, and its non-s8 all-gather bytes over the modeled per-chunk gather
pipeline (FWD + unbuffered-BWD re-gathers) become the ``gather_bf16``
factor t_gather applies to manual plans. The two collective families are
split per fit — s8 belongs to t_reduce, bf16 gathers to t_gather. A zero2
(up-front gather) plan is measured alongside for the record.

The EF-residual memory term is calibrated the same run: the fp32 residual
tree's bytes over the grad bytes, measured from the built train state specs.

The serve-side ``h2d_page`` factor (ISSUE-5) is calibrated from a *paged
decode* program: the page-table KV cache (repro.serve.paging) fetches each
cold page as a page-shaped slice of the host-resident cold store inside the
decode repeat scan, and those slices are countable in the lowered program —
cold-store operand shape -> page result shape, a signature nothing else in
the program produces. The fit is structural truth for the fetch pipeline:
measured page-fetch bytes per scan iteration over the modeled
pages x (k,v) x attention-positions inventory at factor 1. A healthy build
fits ~1.0; drift means fetches were duplicated (remat regression) or
hoisted/merged out of the per-page pipeline (the full-cache-gather
regression paging exists to avoid). The planner's feasibility term
multiplies this factor into the analytic cold-page bound
(cost_model.t_page_fetch; the hot-window discount stays analytic because
page residency is decided at run time by the write pointer).

Usage:
    PYTHONPATH=src python benchmarks/calibrate_wire.py [--out reports/]
        [--install] [--dry-run]

``--install`` also writes src/repro/core/wire_calibration.json (the copy the
cost model auto-loads, committed per backend). ``--dry-run`` is the CI smoke
mode: measure the anchor configs (uncompressed xla + zero-manual int8 + the
paged-decode h2d_page fit), sanity-check the fitted factors against their
bands, write nothing, exit non-zero on drift.
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # must precede jax import; mirror CI's 4 devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core.chunks import chunk_inventory
from repro.core.cost_model import CALIBRATION_SCHEMA_VERSION
from repro.core.plan import MemoryPlan
from repro.launch.roofline import parse_collectives
from repro.train.step_builder import build_train_step

# (key, sync_mode, grad_compress, n_persist of the 4-chunk plan, zero_stage,
#  n_buffer)
CONFIGS = [
    ("xla/none", "xla", "none", 4, 3, 0),
    ("xla/bf16", "xla", "bf16", 4, 3, 0),
    ("xla/int8_ef", "xla", "int8_ef", 4, 3, 0),
    ("manual/bf16", "manual", "bf16", 4, 3, 0),
    ("manual/int8_ef", "manual", "int8_ef", 4, 3, 0),
    # ZeRO-sharded manual, both dataflows. "zero3" (lazy per-chunk gather)
    # is the fit source for the "int8_ef_rs" reduce-scatter factor (the s8
    # all_to_all payload of the gather VJP) AND the "gather_bf16" param-
    # gather factor (its bf16 all-gathers vs the modeled per-chunk topology
    # bytes); "zero2" (up-front gather) is measured for the record, as is
    # the fully-buffered "zero3_buf" (ISSUE-7: the prefetch pipeline must
    # keep the gather census unchanged — same gathers, earlier issue slots,
    # no BWD re-gathers per the buffered branch of the modeled pipeline).
    ("manual_zero2/int8_ef", "manual", "int8_ef", 0, 2, 0),
    ("manual_zero3/int8_ef", "manual", "int8_ef", 0, 3, 0),
    ("manual_zero3_buf/int8_ef", "manual", "int8_ef", 0, 3, 4),
]
DRY_RUN_KEYS = ("xla/none", "manual_zero3/int8_ef")


def _spec_bytes(tree) -> int:
    return sum(
        int(jnp.dtype(s.dtype).itemsize) * int(jnp.prod(jnp.array(s.shape)))
        if s.shape else int(jnp.dtype(s.dtype).itemsize)
        for s in jax.tree.leaves(tree)
    )


def _wire_bytes(hlo: str) -> tuple[float, float, float, float]:
    """(raw, fp32-corrected, s8-only, param-gather) per-chip serialized
    collective bytes.

    The corrected number halves fp32 payloads — the CPU backend upcasts bf16
    compute to fp32, dragging the gradient reduce with it; corrected
    approximates what a bf16-native backend moves (see launch/roofline.py).
    The s8-only number isolates the compressed gradient payload — what the
    reduce-scatter fit needs, because the zero-manual program also carries
    bf16 param all-gathers. Those belong to the fourth number: non-s8
    all-gather bytes (fp32-corrected), the measurement side of the
    ``gather_bf16`` factor t_gather consumes.
    """
    ops = parse_collectives(hlo)
    raw = sum(o.wire_bytes() * o.multiplier for o in ops)
    corrected = sum(
        o.wire_bytes() * o.multiplier * (0.5 if o.dtype == "f32" else 1.0) for o in ops
    )
    s8 = sum(o.wire_bytes() * o.multiplier for o in ops if o.dtype in ("s8", "u8"))
    gather = sum(
        o.wire_bytes() * o.multiplier * (0.5 if o.dtype == "f32" else 1.0)
        for o in ops if o.kind == "all-gather" and o.dtype not in ("s8", "u8")
    )
    return raw, corrected, s8, gather


def calibrate_serve(arch: str = "llama3-405b", *, seq_len: int = 64,
                    batch: int = 4, page_size: int = 8, n_hot: int = 2) -> dict:
    """Fit the ``h2d_page`` factor from a compiled paged decode step.

    Measured: page-shaped slices of the cold store in the lowered program
    (shape-matched: (B, S, kv, hd) operand -> (B, P, kv, hd) result; the hot
    ring has a different operand shape whenever n_hot < n_pages, so the match
    is unambiguous), in bytes per decode repeat. Modeled at factor 1: every
    page of both k and v sliced exactly once per attention position —
    n_pages x 2 x attn_positions x page_bytes. Global (pre-partition) bytes
    on both sides, so the ratio is chip-count free.
    """
    import re

    from repro.configs.base import ShapeConfig
    from repro.models import kvcache as KV
    from repro.models.model import superblock_period
    from repro.serve.paging import choose_paging
    from repro.train.step_builder import build_decode_step

    cfg = reduced(ARCHS[arch])
    shape = ShapeConfig("calib-serve", seq_len, batch, "decode")
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    s_kv = KV.cache_len(cfg, seq_len)
    spec = choose_paging(s_kv, page_size, n_hot)
    assert spec.n_hot < spec.n_pages, "need cold pages to measure fetches"
    plan = MemoryPlan(n_chunks=4, n_blocks=2, n_persist=4, n_host=spec.n_cold)
    art = build_decode_step(cfg, plan, mesh, shape, paging=spec)
    lowered = art.lower(donate=False)
    text = lowered.as_text()

    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    dt = jnp.dtype(cfg.dtype)
    cold_t = f"tensor<{batch}x{s_kv}x{kv}x{hd}x[a-z0-9]+>"
    page_t = f"tensor<{batch}x{spec.page_size}x{kv}x{hd}x[a-z0-9]+>"
    n_slices = len(re.findall(
        rf"slice.*\({cold_t}\) -> {page_t}", text))
    page_bytes = batch * spec.page_size * kv * hd * dt.itemsize
    measured = n_slices * page_bytes
    attn_pos = sum(1 for j in range(superblock_period(cfg))
                   if cfg.mixer_at(j) == "attention")
    modeled = spec.n_pages * 2 * attn_pos * page_bytes
    # the compiled program must still lower (the slice census is pre-opt;
    # compiling guards against the paged path rotting into a compile error)
    lowered.compile()
    return {
        "h2d_page": round(measured / max(modeled, 1), 4),
        "fit": {
            "arch": arch, "spec": dataclasses_asdict_safe(spec),
            "page_slices": n_slices, "page_bytes": page_bytes,
            "measured_bytes": measured, "modeled_factor1_bytes": modeled,
        },
    }


def _pallas_block_census(fn, *args) -> dict:
    """Grid + per-input block-byte census of the single pallas_call inside
    ``fn``, from its traced jaxpr. Structural truth for the kernel fits: the
    block specs *are* what the kernel streams per grid step, so
    grid_steps x block_bytes is the kernel's HBM read inventory."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    eqns: list = []

    def walk(jx):
        for eq in jx.eqns:
            if eq.primitive.name == "pallas_call":
                eqns.append(eq)
            for v in eq.params.values():
                inner = getattr(v, "jaxpr", None)
                if hasattr(inner, "eqns"):
                    walk(inner)
                elif hasattr(v, "eqns"):
                    walk(v)

    walk(jaxpr.jaxpr)
    (eq,) = eqns
    gm = eq.params["grid_mapping"]
    steps = 1
    for d in gm.grid:
        steps *= int(d)
    inputs = []
    for bm, invar in zip(gm.block_mappings, eq.invars):
        shape = tuple(int(d) for d in bm.block_shape
                      if isinstance(d, (int,)) or getattr(d, "__int__", None))
        n = 1
        for d in shape:
            n *= int(d)
        inputs.append({
            "block_shape": tuple(int(d) for d in shape),
            "bytes_per_step": n * jnp.dtype(invar.aval.dtype).itemsize,
        })
    return {"grid_steps": steps, "inputs": inputs}


def calibrate_kernels(*, b: int = 2, hq: int = 8, hkv: int = 2,
                      s_kv: int = 64, page_size: int = 8, n_hot: int = 2,
                      hd: int = 32, z: int = 4, n: int = 4096) -> dict:
    """Fit the ``paged_attn`` and ``fused_quant`` pass factors from the
    traced pallas_call block census of the jitted kernel wrappers.

    * ``paged_attn``: measured = grid_steps x the four K/V stream blocks
      (hot-k, cold-k, hot-v, cold-v tiles — identified by their
      (1, page_size, hd) block shape; the q block is (1, group, hd), kept
      distinct). Modeled at factor 1: KERNEL_CACHE_PASSES passes over the
      (k, v) cache bytes — exactly what
      cost_model.paged_cache_read_bytes charges the kernel branch.
    * ``fused_quant``: measured = grid_steps x the fp32 chunk block.
      Modeled at factor 1: one fp32 read pass over the (z, n) working set —
      what t_reduce's fused-quantize pricing charges at 1 pass.

    A healthy build fits 1.0 on both; drift means the kernel's block specs
    grew extra streams (a transient rematerialized, a block revisited) and
    the cost model's pass counts no longer describe the kernel. Falls back
    to the analytic factors (1.0, recorded with the error) if the jaxpr
    introspection API moved."""
    from repro.kernels.fused_quant import fused_quantize_ef
    from repro.kernels.paged_attention import paged_attention

    assert hq // hkv != page_size, "q/kv block shapes must stay distinguishable"
    w = n_hot * page_size
    f32 = jnp.float32
    pa_args = (jnp.zeros((b, 1, hq, hd), f32),
               jnp.zeros((b, w, hkv, hd), f32),
               jnp.zeros((b, w, hkv, hd), f32),
               jnp.zeros((b, s_kv, hkv, hd), f32),
               jnp.zeros((b, s_kv, hkv, hd), f32),
               jnp.zeros((b, s_kv), bool),
               jnp.zeros((b, s_kv), f32))
    fq_args = (jnp.zeros((z, n), f32), jnp.int32(0))
    try:
        pa = _pallas_block_census(
            lambda *a: paged_attention(*a, n_hot=n_hot, interpret=True), *pa_args)
        fq = _pallas_block_census(
            lambda c, m: fused_quantize_ef(c, m, interpret=True), *fq_args)
    except Exception as e:  # pragma: no cover - jaxpr API drift
        return {"paged_attn": 1.0, "fused_quant": 1.0,
                "fit": {"error": f"pallas_call introspection failed: {e}"}}
    from repro.core.cost_model import KERNEL_CACHE_PASSES

    kv_stream = [r for r in pa["inputs"]
                 if r["block_shape"] == (1, page_size, hd)
                 and r["bytes_per_step"] == page_size * hd * 4]
    pa_measured = pa["grid_steps"] * sum(r["bytes_per_step"] for r in kv_stream)
    pa_modeled = KERNEL_CACHE_PASSES * 2 * b * s_kv * hkv * hd * 4
    ch_stream = [r for r in fq["inputs"] if r["block_shape"] == (1, n)
                 and r["bytes_per_step"] == n * 4]
    fq_measured = fq["grid_steps"] * sum(r["bytes_per_step"] for r in ch_stream)
    fq_modeled = z * n * 4
    return {
        "paged_attn": round(pa_measured / max(pa_modeled, 1), 4),
        "fused_quant": round(fq_measured / max(fq_modeled, 1), 4),
        "fit": {
            "paged_attn": {"grid_steps": pa["grid_steps"],
                           "kv_stream_blocks": len(kv_stream),
                           "measured_bytes": pa_measured,
                           "modeled_factor1_bytes": pa_modeled},
            "fused_quant": {"grid_steps": fq["grid_steps"],
                            "measured_bytes": fq_measured,
                            "modeled_factor1_bytes": fq_modeled},
        },
    }


def calibrate_act_compress(*, b: int = 2, s: int = 64, d: int = 256) -> dict:
    """Fit the ``act_compress`` pass factor of the compressed activation
    policies (compress8/compress16) from the traced pallas_call block census
    of the fused quantize kernel at *activation* shapes.

    The quantize-on-save seam (models/model.compress_act) reshapes each
    (B, S, D) site tensor to (B*S, D) rows and streams it through the same
    fused int8 kernel the gradient path uses. Measured: grid_steps x the
    fp32 row block — the kernel's read inventory per site. Modeled at
    factor 1: one fp32 pass over the working set, which is the read side of
    what cost_model.t_act_compress_pass charges per quantize/dequantize
    stream (the compressed write rides the same factor). A healthy build
    fits 1.0; drift means the kernel re-reads rows and the policy search is
    under-pricing compression. Falls back to the analytic factor if the
    jaxpr introspection API moved."""
    from repro.kernels.fused_quant import fused_quantize_ef

    rows = b * s
    args = (jnp.zeros((rows, d), jnp.float32), jnp.int32(0))
    try:
        cen = _pallas_block_census(
            lambda c, m: fused_quantize_ef(c, m, interpret=True), *args)
    except Exception as e:  # pragma: no cover - jaxpr API drift
        return {"act_compress": 1.0,
                "fit": {"error": f"pallas_call introspection failed: {e}"}}
    ch = [r for r in cen["inputs"] if r["block_shape"] == (1, d)
          and r["bytes_per_step"] == d * 4]
    measured = cen["grid_steps"] * sum(r["bytes_per_step"] for r in ch)
    modeled = rows * d * 4
    return {
        "act_compress": round(measured / max(modeled, 1), 4),
        "fit": {"grid_steps": cen["grid_steps"], "row_blocks": len(ch),
                "measured_bytes": measured, "modeled_factor1_bytes": modeled},
    }


def dataclasses_asdict_safe(obj) -> dict:
    import dataclasses as _dc

    return _dc.asdict(obj) if _dc.is_dataclass(obj) else dict(obj)


def modeled_overlap(steps_model: str, mesh) -> dict:
    """Hidden-comm fraction of the reference buffered manual zero3 plan:
    ``1 - t_overlap / t_serial`` from the cost model's two pricings of the
    *same* plan (overlap: per-chunk max(compute, comm); serial: their sum —
    see cost_model.estimate_runtime). Purely modeled — the forced-host CPU
    backend executes collectives inline on the compute cores, so a measured
    wall-clock fraction here would say nothing about overlap; the dry-run
    band instead guards the pricing identity itself: some comm must hide
    (fraction > 0 whenever any chunk has both compute and comm) and not all
    time can vanish (fraction well below 1 — compute is still on the
    critical path). Recorded in the installed calibration per backend as an
    informational key; ``load_wire_calibration`` ignores it, so pre-ISSUE-7
    JSONs without it load unchanged."""
    import dataclasses as _dc

    from repro.core import build_workload, estimate_runtime
    from repro.core.hardware import LOCAL_CPU_HW, MeshSpec

    cfg = reduced(ARCHS[steps_model])
    shape = ShapeConfig("calib", 32, 4, "train")
    mspec = MeshSpec(tuple(mesh.devices.shape), tuple(mesh.axis_names))
    w = build_workload(cfg, shape, mspec, LOCAL_CPU_HW)
    plan = MemoryPlan(n_chunks=w.n_chunks, n_blocks=w.n_blocks,
                      n_buffer=w.n_chunks, grad_compress="int8_ef",
                      sync_mode="manual", zero_stage=3)
    t_ov = estimate_runtime(w, plan).t_iteration
    t_ser = estimate_runtime(w, _dc.replace(plan, overlap=False)).t_iteration
    return {
        "plan": plan.describe(),
        "t_overlap_s": round(t_ov, 6),
        "t_serial_s": round(t_ser, 6),
        "hidden_comm_fraction": round(1.0 - t_ov / max(t_ser, 1e-12), 4),
    }


def calibrate(steps_model: str = "llama3-405b", keys: tuple | None = None) -> dict:
    """Measure every (sync_mode, grad_compress, layout) config; return the
    backend entry. ``keys`` restricts to a subset (--dry-run smoke)."""
    cfg = reduced(ARCHS[steps_model])
    shape = ShapeConfig("calib", 32, 4, "train")
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    z = n_dev

    chunks = chunk_inventory(cfg)
    grad_bytes = sum(c.grad_bytes for c in chunks)

    def modeled_factor1(key: str) -> float:
        """Per-chip wire bytes the cost model predicts at wire_factor == 1
        (mirror of cost_model.t_reduce's topology terms)."""
        if key.startswith("manual_zero"):
            return grad_bytes * (z - 1) / z  # all_to_all reduce-scatter
        if key == "manual/int8_ef":
            return grad_bytes * (z - 1)  # gather-based: z-1 payloads received
        return 2.0 * grad_bytes * (z - 1) / z  # ring all-reduce, replicated grads

    def modeled_gather_factor1(plan) -> float:
        """Per-chip param-gather bytes at gather_bf16 == 1: the cost model's
        per-chunk pipeline — every non-persistent chunk gathered in FWD, and
        *block* chunks re-gathered in BWD when unbuffered (except the first
        chunk BWD visits, whose weights are still live; embed/head/encoder
        are gathered at point of use outside any remat region, so their
        gathered leaves survive to BWD like the xla path's fetch) — at ring
        topology."""
        fwd = sum(c.param_bytes for c in chunks
                  if plan.chunk_placement(c.index) != "persist")
        order = list(range(len(chunks) - 1, -1, -1))
        bwd = sum(
            chunks[i].param_bytes for i in order[1:]
            if chunks[i].is_block
            and plan.chunk_placement(i) != "persist"
            and not plan.chunk_buffered(i))
        return (fwd + bwd) * (z - 1) / z

    measured: dict[str, dict] = {}
    ef_factor = None
    for key, sync_mode, compress, n_persist, zero_stage, n_buffer in CONFIGS:
        if keys is not None and key not in keys:
            continue
        plan = MemoryPlan(n_chunks=4, n_blocks=2, n_persist=n_persist,
                          grad_compress=compress, sync_mode=sync_mode,
                          zero_stage=zero_stage, n_buffer=n_buffer)
        art = build_train_step(cfg, plan, mesh, shape)
        compiled = art.lower(donate=False).compile()
        raw, corrected, s8, gather = _wire_bytes(compiled.as_text())
        measured[key] = {
            "wire_bytes_raw": raw,
            "wire_bytes_corrected": corrected,
            "wire_bytes_s8": s8,
            "wire_bytes_param_gather": gather,
            "modeled_factor1_bytes": modeled_factor1(key),
            "modeled_gather_factor1_bytes": modeled_gather_factor1(plan),
        }
        if compress == "int8_ef" and n_persist == 4 and ef_factor is None:
            ef_factor = _spec_bytes(art.state_specs["ef"]) / grad_bytes

    # fit: xla factors are relative to the measured uncompressed reduce (same
    # collective inventory, so overheads cancel); manual factors against the
    # model's own topology prediction at factor 1 — the DDP gather fit uses
    # all corrected collective bytes (its program has no other collectives),
    # the zero3 reduce-scatter fit uses only the s8 bytes and the gather fit
    # only the non-s8 all-gather bytes (the zero programs carry both, and
    # t_reduce/t_gather price them separately)
    factors: dict[str, dict] = {"xla": {"none": 1.0}, "manual": {"none": 1.0}}
    xla_base = max(measured.get("xla/none", {}).get("wire_bytes_corrected", 0.0), 1.0)
    for key, sync_mode, compress, _, _, _ in CONFIGS[1:]:
        if key not in measured:
            continue
        m = measured[key]
        if sync_mode == "xla":
            factors["xla"][compress] = round(m["wire_bytes_corrected"] / xla_base, 4)
        elif key == "manual_zero3/int8_ef":
            factors["manual"]["int8_ef_rs"] = round(
                m["wire_bytes_s8"] / m["modeled_factor1_bytes"], 4)
            factors["manual"]["gather_bf16"] = round(
                m["wire_bytes_param_gather"]
                / max(m["modeled_gather_factor1_bytes"], 1.0), 4)
        elif key in ("manual_zero2/int8_ef", "manual_zero3_buf/int8_ef"):
            pass  # recorded in `fit`; zero3 is the fit source for both factors
        else:
            factors["manual"][compress] = round(
                m["wire_bytes_corrected"] / m["modeled_factor1_bytes"], 4)

    # serve-side page-fetch factor (paged decode; independent program)
    serve = calibrate_serve(steps_model)
    factors["serve"] = {"h2d_page": serve["h2d_page"]}

    # fused-kernel pass factors (ISSUE-8; traced block census, no compile)
    kernels = calibrate_kernels()
    factors["serve"]["paged_attn"] = kernels["paged_attn"]
    factors["manual"]["fused_quant"] = kernels["fused_quant"]

    # activation quantize-pass factor (ISSUE-9; same census, activation shapes).
    # The compress seam is sync-mode independent — the same kernel runs under
    # both the xla and manual paths — so the one fit lands in both tables.
    act = calibrate_act_compress()
    factors["xla"]["act_compress"] = act["act_compress"]
    factors["manual"]["act_compress"] = act["act_compress"]

    entry = {
        "wire_factors": factors,
        "overlap": modeled_overlap(steps_model, mesh),
        "fit": {
            "model": steps_model,
            "mesh": list(mesh.devices.shape),
            "grad_bytes": grad_bytes,
            "measured": measured,
            "serve": serve["fit"],
            "kernels": kernels["fit"],
            "act_compress": act["fit"],
        },
    }
    if ef_factor is not None:
        entry["ef_residual_factor"] = round(ef_factor, 4)
    return entry


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "reports")))
    ap.add_argument("--install", action="store_true",
                    help="also write src/repro/core/wire_calibration.json")
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: measure the anchor configs, check the "
                         "fitted factors are sane, write nothing")
    args = ap.parse_args()

    backend = jax.default_backend()
    if args.dry_run:
        entry = calibrate(keys=DRY_RUN_KEYS)
        rs = entry["wire_factors"]["manual"].get("int8_ef_rs")
        gf = entry["wire_factors"]["manual"].get("gather_bf16")
        base = entry["fit"]["measured"]["xla/none"]["wire_bytes_corrected"]
        print(f"[calibrate_wire --dry-run] backend={backend} "
              f"xla/none corrected bytes={base:.0f} int8_ef_rs={rs} "
              f"gather_bf16={gf}")
        if base <= 0:
            print("[calibrate_wire --dry-run] FAIL: no collective bytes "
                  "measured for the uncompressed reduce")
            return 1
        if rs is None or not (0.1 <= rs <= 1.2):
            print("[calibrate_wire --dry-run] FAIL: reduce-scatter factor "
                  f"{rs} outside the sane band [0.1, 1.2] — the s8 payload "
                  "is no longer (or no longer only) what crosses the wire")
            return 1
        if gf is None or not (0.2 <= gf <= 3.0):
            print("[calibrate_wire --dry-run] FAIL: param-gather factor "
                  f"{gf} outside the sane band [0.2, 3.0] — the zero3 lazy "
                  "per-chunk gathers no longer match the modeled per-chunk "
                  "pipeline (up-front gather regression, or gathers duplicated"
                  " beyond the BWD re-gather)")
            return 1
        hp = entry["wire_factors"].get("serve", {}).get("h2d_page")
        print(f"[calibrate_wire --dry-run] h2d_page={hp}")
        if hp is None or not (0.5 <= hp <= 2.0):
            print("[calibrate_wire --dry-run] FAIL: paged-decode page-fetch "
                  f"factor {hp} outside the sane band [0.5, 2.0] — cold "
                  "pages are being fetched more than once per layer "
                  "(duplication) or the per-page pipeline collapsed into a "
                  "full-cache gather (hoist regression)")
            return 1
        pa = entry["wire_factors"]["serve"].get("paged_attn")
        fq = entry["wire_factors"]["manual"].get("fused_quant")
        print(f"[calibrate_wire --dry-run] paged_attn={pa} fused_quant={fq}")
        if pa is None or not (0.5 <= pa <= 2.0):
            print("[calibrate_wire --dry-run] FAIL: paged-attention kernel "
                  f"pass factor {pa} outside the sane band [0.5, 2.0] — the "
                  "kernel's block specs no longer stream the cost model's "
                  "KERNEL_CACHE_PASSES passes over the cache (an extra "
                  "stream or revisit crept into the block census)")
            return 1
        if fq is None or not (0.5 <= fq <= 2.0):
            print("[calibrate_wire --dry-run] FAIL: fused-quantize pass "
                  f"factor {fq} outside the sane band [0.5, 2.0] — the "
                  "kernel no longer reads the chunk working set exactly "
                  "once per grid step")
            return 1
        ac = entry["wire_factors"]["manual"].get("act_compress")
        print(f"[calibrate_wire --dry-run] act_compress={ac}")
        if ac is None or not (0.5 <= ac <= 2.0):
            print("[calibrate_wire --dry-run] FAIL: activation quantize-pass "
                  f"factor {ac} outside the sane band [0.5, 2.0] — the "
                  "compress8 save seam no longer streams each activation "
                  "site once per quantize pass, so the per-block policy "
                  "search is mispricing compression")
            return 1
        hf = entry.get("overlap", {}).get("hidden_comm_fraction")
        print(f"[calibrate_wire --dry-run] hidden_comm_fraction={hf}")
        if hf is None or not (0.02 <= hf <= 0.95):
            print("[calibrate_wire --dry-run] FAIL: modeled hidden-comm "
                  f"fraction {hf} outside the sane band [0.02, 0.95] — the "
                  "overlap pricing no longer hides any manual comm under "
                  "compute (max() degenerated to the serial sum) or claims "
                  "to hide nearly the whole step (comm can only hide, never "
                  "erase the compute critical path)")
            return 1
        print("[calibrate_wire --dry-run] OK")
        return 0

    entry = calibrate()
    doc = {
        "generated_by": "benchmarks/calibrate_wire.py",
        "version": CALIBRATION_SCHEMA_VERSION,
        "backends": {backend: entry},
    }
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, "wire_calibration.json")
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[calibrate_wire] backend={backend} factors={entry['wire_factors']} "
          f"ef_residual_factor={entry['ef_residual_factor']}")
    print(f"[calibrate_wire] wrote {out_path}")

    if args.install:
        install_path = os.path.abspath(os.path.join(
            os.path.dirname(__file__), "..", "src", "repro", "core",
            "wire_calibration.json"))
        existing = {}
        if os.path.exists(install_path):
            with open(install_path) as f:
                existing = json.load(f).get("backends", {})
        # merge per backend: re-running on another backend extends the file;
        # drop the bulky per-config measurements from the installed copy
        existing[backend] = {k: v for k, v in entry.items() if k != "fit"}
        with open(install_path, "w") as f:
            json.dump({"generated_by": doc["generated_by"],
                       "version": CALIBRATION_SCHEMA_VERSION,
                       "backends": existing}, f, indent=2)
        print(f"[calibrate_wire] installed {install_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
