"""Fit gradient-sync wire-cost factors against measured dry-run bytes.

The cost model prices the gradient reduce as

    t_reduce = grad_bytes * wire_factor(sync_mode, grad_compress) * topology / bw

where ``topology`` is the ring all-reduce term for the xla path and the
gather-based term for the manual path (see cost_model.t_reduce). This script
*measures* the collective bytes each (sync_mode, grad_compress) configuration
actually compiles to — build_train_step -> lower -> compile -> parse the HLO
with the roofline collective walker — and fits

    wire_factor = measured_wire_bytes / modeled_wire_bytes_at_factor_1

per backend, emitting a calibration JSON that ``core/cost_model.py`` loads
(``load_wire_calibration``; the packaged copy under src/repro/core/ is the
default). Two facts the fit makes honest, replacing the hand-set
GRAD_WIRE_FACTOR constant:

  * sync_mode="xla": XLA's reduce moves the *raw* gradients; the int8/bf16
    numerics are applied after, so the measured factor is ~1.0 — in-jit
    compression is accounting fiction on the wire;
  * sync_mode="manual": the int8 payload is what crosses the link (s8
    all-gathers in the HLO), so the factor reflects the real quantization
    ratio.

The EF-residual memory term is calibrated the same run: the fp32 residual
tree's bytes over the grad bytes, measured from the built train state specs.

Usage:
    PYTHONPATH=src python benchmarks/calibrate_wire.py [--out reports/] [--install]

``--install`` also writes src/repro/core/wire_calibration.json (the copy the
cost model auto-loads, committed per backend).
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:  # must precede jax import; mirror CI's 4 devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core.chunks import chunk_inventory
from repro.core.plan import MemoryPlan
from repro.launch.roofline import parse_collectives
from repro.train.step_builder import build_train_step

CONFIGS = [  # (sync_mode, grad_compress)
    ("xla", "none"),
    ("xla", "bf16"),
    ("xla", "int8_ef"),
    ("manual", "bf16"),
    ("manual", "int8_ef"),
]


def _spec_bytes(tree) -> int:
    return sum(
        int(jnp.dtype(s.dtype).itemsize) * int(jnp.prod(jnp.array(s.shape)))
        if s.shape else int(jnp.dtype(s.dtype).itemsize)
        for s in jax.tree.leaves(tree)
    )


def _wire_bytes(hlo: str) -> tuple[float, float]:
    """(raw, fp32-corrected) per-chip serialized collective bytes in the HLO.

    The corrected number halves fp32 payloads — the CPU backend upcasts bf16
    compute to fp32, dragging the gradient reduce with it; corrected
    approximates what a bf16-native backend moves (see launch/roofline.py).
    """
    ops = parse_collectives(hlo)
    raw = sum(o.wire_bytes() * o.multiplier for o in ops)
    corrected = sum(
        o.wire_bytes() * o.multiplier * (0.5 if o.dtype == "f32" else 1.0) for o in ops
    )
    return raw, corrected


def calibrate(steps_model: str = "llama3-405b") -> dict:
    """Measure every (sync_mode, grad_compress) config; return the backend entry."""
    cfg = reduced(ARCHS[steps_model])
    shape = ShapeConfig("calib", 32, 4, "train")
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    z = n_dev

    chunks = chunk_inventory(cfg)
    grad_bytes = sum(c.grad_bytes for c in chunks)

    def modeled_factor1(sync_mode: str, compress: str) -> float:
        """Per-chip wire bytes the cost model predicts at wire_factor == 1
        (mirror of cost_model.t_reduce's topology terms)."""
        if sync_mode == "manual" and compress == "int8_ef":
            return grad_bytes * (z - 1)  # gather-based: z-1 payloads received
        return 2.0 * grad_bytes * (z - 1) / z  # ring all-reduce, replicated grads

    measured: dict[str, dict] = {}
    base_plan = dict(n_chunks=4, n_blocks=2, n_persist=4)
    ef_factor = None
    for sync_mode, compress in CONFIGS:
        plan = MemoryPlan(**base_plan, grad_compress=compress, sync_mode=sync_mode)
        art = build_train_step(cfg, plan, mesh, shape)
        compiled = art.lower(donate=False).compile()
        raw, corrected = _wire_bytes(compiled.as_text())
        measured[f"{sync_mode}/{compress}"] = {
            "wire_bytes_raw": raw,
            "wire_bytes_corrected": corrected,
            "modeled_factor1_bytes": modeled_factor1(sync_mode, compress),
        }
        if compress == "int8_ef" and ef_factor is None:
            ef_factor = _spec_bytes(art.state_specs["ef"]) / grad_bytes

    # fit: xla factors are relative to the measured uncompressed reduce (same
    # collective inventory, so overheads cancel); manual factors against the
    # model's own gather-topology prediction at factor 1
    xla_base = max(measured["xla/none"]["wire_bytes_corrected"], 1.0)
    factors = {"xla": {"none": 1.0}, "manual": {"none": 1.0}}
    for sync_mode, compress in CONFIGS[1:]:
        m = measured[f"{sync_mode}/{compress}"]["wire_bytes_corrected"]
        if sync_mode == "xla":
            factors["xla"][compress] = round(m / xla_base, 4)
        else:
            factors["manual"][compress] = round(
                m / measured[f"{sync_mode}/{compress}"]["modeled_factor1_bytes"], 4)

    return {
        "wire_factors": factors,
        "ef_residual_factor": round(ef_factor, 4),
        "fit": {
            "model": steps_model,
            "mesh": list(mesh.devices.shape),
            "grad_bytes": grad_bytes,
            "measured": measured,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "reports")))
    ap.add_argument("--install", action="store_true",
                    help="also write src/repro/core/wire_calibration.json")
    args = ap.parse_args()

    backend = jax.default_backend()
    entry = calibrate()
    doc = {
        "generated_by": "benchmarks/calibrate_wire.py",
        "backends": {backend: entry},
    }
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, "wire_calibration.json")
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[calibrate_wire] backend={backend} factors={entry['wire_factors']} "
          f"ef_residual_factor={entry['ef_residual_factor']}")
    print(f"[calibrate_wire] wrote {out_path}")

    if args.install:
        install_path = os.path.abspath(os.path.join(
            os.path.dirname(__file__), "..", "src", "repro", "core",
            "wire_calibration.json"))
        existing = {}
        if os.path.exists(install_path):
            with open(install_path) as f:
                existing = json.load(f).get("backends", {})
        # merge per backend: re-running on another backend extends the file;
        # drop the bulky per-config measurements from the installed copy
        existing[backend] = {k: v for k, v in entry.items() if k != "fit"}
        with open(install_path, "w") as f:
            json.dump({"generated_by": doc["generated_by"], "backends": existing},
                      f, indent=2)
        print(f"[calibrate_wire] installed {install_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
