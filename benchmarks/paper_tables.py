"""Paper-table reproductions through the cost models.

Each function mirrors one table/figure of the paper, run on the paper's own
testbeds (4x RTX 3090, 4x A100-80G) via the calibrated HardwareSpecs. The
point is faithfulness of the *mechanism*: the same planner + cost models that
drive the TPU build, evaluated under the paper's conditions, should reproduce
the paper's qualitative structure (max model sizes, speedup ordering,
config-vs-batch-size trends) — those claims are asserted in
tests/test_paper_claims.py.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ShapeConfig
from repro.configs.paper_models import PAPER_MODELS, _gpt2
from repro.core import build_workload, estimate_memory, estimate_runtime, search
from repro.core.baselines import BASELINES
from repro.core.hardware import A100_80G, RTX_3090, HardwareSpec, MeshSpec
from repro.core.plan import MemoryPlan

GPU1 = MeshSpec((1,), ("data",))
GPU4 = MeshSpec((4,), ("data",))


def gpt2_sized(billions: float):
    """GPT-2 scaled like the paper (Table 1 geometry, layers stretched)."""
    base = {10: (4096, 48, 32), 15: (8192, 18, 64), 20: (8192, 24, 64),
            30: (8192, 36, 64), 40: (8192, 50, 64)}
    if billions <= 2:
        return _gpt2(f"gpt2-{billions:g}b", 2048, max(int(billions * 18), 2), 16)
    hidden, _, heads = base[min(base, key=lambda k: abs(k - billions))]
    # params ~= 12 * L * h^2 (+ embeddings): solve L
    layers = max(int(billions * 1e9 / (12 * hidden * hidden)), 1)
    return _gpt2(f"gpt2-{billions:g}b", hidden, layers, heads)


def max_trainable_size(hw: HardwareSpec, mesh: MeshSpec, planner: str = "protrain",
                       batch: int = 4) -> float:
    """Binary-search the largest GPT-2 (billions) that fits (Table 2)."""
    lo, hi = 0.5, 120.0
    feasible_at = 0.0
    while hi - lo > 1.0:
        mid = (lo + hi) / 2
        cfg = gpt2_sized(mid)
        shape = ShapeConfig("probe", 1024, batch, "train")
        w = build_workload(cfg, shape, mesh, hw)
        cap = hw.hbm_bytes * 0.92
        if planner == "protrain":
            res = search(w, capacity_bytes=cap)
            ok = res.feasible
        else:
            plan = BASELINES[planner](w, cap)
            mem = estimate_memory(w, plan)
            host_need = 0.0  # host capacity check below
            ok = mem.peak < cap
        if ok:
            # host DRAM must also hold the offloaded states (16 B/param)
            from repro.core.chunks import chunk_inventory, model_state_bytes

            states = model_state_bytes(chunk_inventory(cfg))
            ok = states <= hw.host_mem_bytes + hw.hbm_bytes * mesh.n_chips
        if ok:
            feasible_at = mid
            lo = mid
        else:
            hi = mid
    return feasible_at


def table2() -> list[dict]:
    rows = []
    for hw, mesh, label in [
        (RTX_3090, GPU1, "3090x1"), (RTX_3090, GPU4, "3090x4"),
        (A100_80G, GPU1, "A100x1"), (A100_80G, GPU4, "A100x4"),
    ]:
        row = {"testbed": label}
        for planner in ("protrain", "deepspeed", "colossalai", "fsdp"):
            row[planner] = round(max_trainable_size(hw, mesh, planner), 1)
        rows.append(row)
    return rows


def fig3_throughput(hw: HardwareSpec = A100_80G) -> list[dict]:
    """Max training throughput, ProTrain vs baselines (best batch size)."""
    models = ["mistral-7b", "gpt2-10b", "llama-13b", "gpt2-20b", "gpt2-30b", "llama-34b"]
    rows = []
    for name in models:
        cfg = PAPER_MODELS.get(name) or gpt2_sized(float(name.split("-")[1][:-1]))
        row = {"model": name}
        for planner in ("protrain", "deepspeed", "colossalai", "fsdp"):
            best = 0.0
            for batch in (8, 32, 64, 128):
                shape = ShapeConfig("b", 1024, batch, "train")
                w = build_workload(cfg, shape, GPU4, hw)
                cap = hw.hbm_bytes * 0.92
                if planner == "protrain":
                    res = search(w, capacity_bytes=cap)
                    if not res.feasible:
                        continue
                    tput = res.runtime.tokens_per_second
                else:
                    plan = BASELINES[planner](w, cap)
                    if estimate_memory(w, plan).peak >= cap:
                        continue
                    tput = estimate_runtime(w, plan).tokens_per_second
                best = max(best, tput)
            row[planner] = round(best)
        row["speedup_vs_best_baseline"] = round(
            row["protrain"] / max(max(row[p] for p in ("deepspeed", "colossalai", "fsdp")), 1), 2
        )
        rows.append(row)
    return rows


def fig5_ablation(hw: HardwareSpec = RTX_3090) -> list[dict]:
    """Disable each optimization for 10B GPT-2 on 4x3090 (Fig. 5)."""
    cfg = PAPER_MODELS["gpt2-10b"]
    rows = []
    for batch in (4, 8, 16):
        shape = ShapeConfig("b", 1024, batch, "train")
        w = build_workload(cfg, shape, GPU4, hw)
        cap = hw.hbm_bytes * 0.92
        res = search(w, capacity_bytes=cap)
        base = res.runtime.t_iteration
        row = {"batch": batch, "t_protrain_s": round(base, 3)}

        # (a) no hierarchical chunk mgmt: no persistent chunks, 3 buffers
        plan_a = dataclasses.replace(res.plan, n_persist=0,
                                     n_buffer=min(3, res.plan.n_chunks))
        row["no_hier_chunks"] = round(estimate_runtime(w, plan_a).t_iteration / base, 3)

        # (b) no overlapped host update: serialize T_cpu after T_bwd
        rt = estimate_runtime(w, res.plan)
        t_no_overlap = rt.t_fwd + rt.t_bwd + rt.t_gpu_optim + rt.t_cpu_optim
        row["no_overlap_update"] = round(t_no_overlap / base, 3)

        # (c) no interleaved block mgmt: checkpoint everything
        plan_c = dataclasses.replace(res.plan, n_swap=0, n_checkpoint=res.plan.n_blocks)
        row["ckpt_all_blocks"] = round(estimate_runtime(w, plan_c).t_iteration / base, 3)
        rows.append(row)
    return rows


def table3_offload(hw: HardwareSpec = A100_80G) -> list[dict]:
    """Throughput with and without offloading (Table 3)."""
    rows = []
    for name in ("mistral-7b", "gpt2-10b", "llama-13b", "gpt2-20b"):
        cfg = PAPER_MODELS.get(name) or gpt2_sized(20)
        best = {}
        for allow_host, label in ((True, "with_offload"), (False, "no_offload")):
            top = 0.0
            for batch in (8, 32, 64, 128, 224):
                shape = ShapeConfig("b", 1024, batch, "train")
                w = build_workload(cfg, shape, GPU4, hw)
                res = search(w, allow_host=allow_host)
                if res.feasible:
                    top = max(top, res.runtime.tokens_per_second)
            best[label] = round(top)
        best["model"] = name
        best["offload_gain"] = round(best["with_offload"] / max(best["no_offload"], 1), 2)
        rows.append(best)
    return rows


def table4_configs() -> list[dict]:
    """Searched configurations (Table 4 analogue)."""
    rows = []
    cases = [
        ("gpt2-1b", 8, RTX_3090), ("gpt2-1b", 64, RTX_3090), ("gpt2-1b", 64, A100_80G),
        ("gpt2-10b", 8, RTX_3090), ("gpt2-10b", 8, A100_80G),
    ]
    for name, batch, hw in cases:
        cfg = PAPER_MODELS[name]
        shape = ShapeConfig("b", 1024, batch, "train")
        w = build_workload(cfg, shape, GPU4, hw)
        res = search(w)
        p = res.plan
        rows.append({
            "model": name, "batch": batch, "hw": hw.name,
            "N_block": p.n_blocks, "n_checkpoint": p.n_checkpoint, "n_swap": p.n_swap,
            "N_chunk": p.n_chunks, "n_persist": p.n_persist, "n_buffer": p.n_buffer,
            "n_host": p.n_host, "feasible": res.feasible,
        })
    return rows
