"""Telemetry end-to-end smoke: train + serve under one registry, with gates.

Runs the two runtime paths the obs subsystem instruments — a short real
training run (the 8-layer toy, 20 jitted steps through ``train_loop`` with a
``DriftMonitor``) and a small paged serving load (``DecodeEngine`` under
chunked admission) — with one ``Telemetry`` handle installed, then writes

  * ``drift_report.json``     — the online measured-vs-modeled report;
  * ``trace.json``            — Chrome-trace/Perfetto export of every span;
  * ``telemetry_metrics.json``— the registry snapshot.

and gates (exit 1 on failure):

  * the drift report parses and both drift ratios sit inside the same
    [1/T, T] band ``estimator_fidelity --fail-threshold`` enforces
    (default 3.0);
  * ``trace.json`` is valid Chrome trace-event JSON (a ``traceEvents``
    list whose "X" events carry numeric ``ts``/``dur``) and non-trivial;
  * every metric documented in ``obs.metrics.DOCUMENTED_METRICS`` (the
    table in docs/observability.md) exists in the registry — a new metric
    that skips the docs, or a doc row that rotted, goes red here.

    PYTHONPATH=src python benchmarks/telemetry_smoke.py --out-dir reports
"""
import argparse
import json
import os
import sys

from repro.compat import ensure_jax_compat

ensure_jax_compat()

import jax  # noqa: E402

from repro import obs  # noqa: E402
from repro.configs import ARCHS, reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core import build_workload  # noqa: E402
from repro.core.hardware import LOCAL_CPU_HW, MeshSpec  # noqa: E402
from repro.core.plan import MemoryPlan  # noqa: E402
from repro.data.pipeline import SyntheticTokenPipeline  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import kvcache as KV  # noqa: E402
from repro.serve import DecodeEngine, Request, choose_paging  # noqa: E402
from repro.train import step_builder as SB  # noqa: E402
from repro.train.loop import LoopConfig, train_loop  # noqa: E402

# the 8-layer toy: small enough for ~1 s CPU steps, big enough that the cost
# model's CPU pricing and the live-array watermark both land well inside the
# acceptance band (measured margins: runtime ~0.9x, memory ~1.1x)
TOY = dict(num_layers=8, d_model=256, d_ff=1024, vocab_size=2048,
           num_heads=4, num_kv_heads=4, head_dim=64)


def train_phase(tel: obs.Telemetry, steps: int, band: float) -> obs.DriftMonitor:
    cfg = reduced(ARCHS["llama3-405b"], **TOY)
    shape = ShapeConfig("tel_smoke", 128, 4, "train")
    mesh = make_local_mesh()
    w = build_workload(cfg, shape, MeshSpec((1, 1), ("data", "model")),
                       LOCAL_CPU_HW)
    plan = MemoryPlan(w.n_chunks, w.n_blocks, n_persist=w.n_chunks)
    mon = obs.DriftMonitor(w, plan, band=band, registry=tel.registry)
    with obs.use_telemetry(tel):  # build records the sync wire inventory
        art = SB.build_train_step(cfg, plan, mesh, shape)
    pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
    train_loop(art, pipe, None,
               LoopConfig(total_steps=steps, checkpoint_every=1 << 30,
                          log_every=max(1, steps // 2)),
               log=tel.log, telemetry=tel, drift=mon)
    return mon


def serve_phase(tel: obs.Telemetry) -> None:
    cfg = reduced(ARCHS["llama3-405b"], **TOY)
    shape = ShapeConfig("tel_smoke_serve", 64, 2, "decode")
    mesh = make_local_mesh()
    s_kv = KV.cache_len(cfg, shape.seq_len)
    paging = choose_paging(s_kv, 8, 2)
    plan = MemoryPlan(3, 2, n_persist=3, n_host=paging.n_cold)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(cfg, plan, mesh, shape, params, paging=paging,
                          admission="chunked", telemetry=tel)
    engine.warmup()
    reqs = [Request(rid, [1 + rid] * (5 + 3 * rid), 6) for rid in range(4)]
    engine.run(reqs, max_steps=500)


def check_chrome_trace(doc: dict) -> list[str]:
    bad = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    if not any(e.get("ph") == "X" for e in evs):
        bad.append("no complete ('X') span events")
    for e in evs:
        if not isinstance(e.get("name"), str) or "ph" not in e:
            bad.append(f"malformed event: {e}")
            break
        if e["ph"] == "X" and not (
                isinstance(e.get("ts"), (int, float))
                and isinstance(e.get("dur"), (int, float))):
            bad.append(f"X event without numeric ts/dur: {e}")
            break
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="reports")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--band", type=float, default=3.0,
                    help="drift acceptance band [1/T, T] (matches "
                         "estimator_fidelity --fail-threshold)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    tel = obs.Telemetry(
        logger=obs.StructuredLogger(
            "telemetry_smoke",
            jsonl_path=os.path.join(args.out_dir, "telemetry_log.jsonl")))
    mon = train_phase(tel, args.steps, args.band)
    serve_phase(tel)

    drift_path = mon.write(os.path.join(args.out_dir, "drift_report.json"))
    trace_path = tel.tracer.write_chrome_trace(
        os.path.join(args.out_dir, "trace.json"), process_name="telemetry_smoke")
    snap_path = os.path.join(args.out_dir, "telemetry_metrics.json")
    with open(snap_path, "w") as f:
        json.dump(tel.registry.snapshot(), f, indent=2)
        f.write("\n")

    failures = []
    with open(drift_path) as f:
        drift = json.load(f)
    for dim in ("runtime", "memory"):
        ratio = drift[dim]["ratio"]
        if not drift[dim]["in_band"]:
            failures.append(f"{dim} drift ratio {ratio} outside "
                            f"[1/{args.band}, {args.band}]")
        else:
            print(f"[telemetry_smoke] {dim} drift ratio "
                  f"{ratio:.3f} in band (band={args.band})")
    with open(trace_path) as f:
        failures += check_chrome_trace(json.load(f))
    missing = sorted(set(obs.DOCUMENTED_METRICS) - tel.registry.names())
    if missing:
        failures.append(f"documented metrics never registered: {missing}")
    else:
        print(f"[telemetry_smoke] all {len(obs.DOCUMENTED_METRICS)} "
              "documented metrics present")
    print(f"[telemetry_smoke] wrote {drift_path}, {trace_path}, {snap_path} "
          f"({len(tel.tracer.events)} trace events)")
    if failures:
        for msg in failures:
            print(f"[telemetry_smoke] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[telemetry_smoke] smoke OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
