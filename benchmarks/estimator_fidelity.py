"""Fig. 6 analogue: predicted vs measured memory and runtime.

We cannot measure TPU wall time in this container; the estimators are
validated on what IS measurable here:
  * peak memory: our analytic estimate vs XLA's buffer assignment
    (compiled memory_analysis) across plans, on a reduced model where the CPU
    backend's fp32-dot inflation is corrected for (x0.5 on dot-derived temps
    is NOT applied — instead we compare with fp32 compute dtype so both sides
    speak fp32);
  * runtime: modeled step time vs measured wall time across plans on CPU
    hardware constants — the paper's claim is *ranking fidelity* (the search
    picks the argmin), so we report trend correlation, not absolute error.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import build_workload, estimate_memory
from repro.core.hardware import LOCAL_CPU_HW as CPU_HW, MeshSpec
from repro.core.plan import MemoryPlan
from repro.launch.mesh import make_local_mesh
from repro.train.step_builder import build_train_step


def _local_mesh_spec(mesh) -> MeshSpec:
    """Analytic MeshSpec matching the actual local mesh — the *memory*
    estimate and the compiled program must agree on sharding degree (CI forces
    4 CPU devices, which shards buffers 4-way)."""
    return MeshSpec(tuple(mesh.devices.shape), tuple(mesh.axis_names))


# For *runtime*, forced host devices are simulated chips sharing one CPU's
# cores: partitioning does not speed up wall time, so the 1-chip model stays
# the right oracle regardless of the local device count.
MESH1 = MeshSpec((1, 1), ("data", "model"))


def plans_under_test(nc: int, nb: int) -> list[tuple[str, MemoryPlan]]:
    return [
        ("resident", MemoryPlan(nc, nb, n_persist=nc)),
        ("ckpt_half", MemoryPlan(nc, nb, n_persist=nc, n_checkpoint=nb // 2)),
        ("ckpt_all", MemoryPlan(nc, nb, n_persist=nc, n_checkpoint=nb)),
        ("zero", MemoryPlan(nc, nb)),
        ("zero_buf", MemoryPlan(nc, nb, n_buffer=nc)),
        ("ubatch2", MemoryPlan(nc, nb, n_persist=nc, microbatch=2)),
        # ISSUE-9 row: uniform compress8 activation policy — the quantize-on-
        # save seam must shrink what XLA keeps live without breaking the
        # analytic estimate (compressed bytes resident, interiors remat)
        ("compress8", MemoryPlan(nc, nb, n_persist=nc,
                                 act_policies=("compress8",) * nb)),
    ]


def manual_plans_under_test(nc: int, nb: int) -> list[tuple[str, MemoryPlan]]:
    """Manual-sync ZeRO plans (ISSUE-4/7): both dataflows plus a buffered
    zero3, so the CI --fail-threshold gate covers the lazy-gather path's
    memory model, not just the xla lowering. The ISSUE-7 rows pin the
    overlap machinery: "manual_zero3_overlap" compiles the prefetch
    pipeline (double-buffered gathers, scan-carried weights) and
    "manual_zero3_serial" its overlap=False twin — their memory must track
    the same estimate, since overlap shifts *when* collectives run, not
    what is resident."""
    mk = lambda **kw: MemoryPlan(nc, nb, grad_compress="int8_ef",  # noqa: E731
                                 sync_mode="manual", **kw)
    return [
        ("manual_zero2", mk(zero_stage=2)),
        ("manual_zero3", mk(zero_stage=3)),
        ("manual_zero3_buf", mk(zero_stage=3, n_buffer=nc)),
        ("manual_zero3_overlap", mk(zero_stage=3, n_buffer=nc, microbatch=2)),
        ("manual_zero3_serial",
         mk(zero_stage=3, n_buffer=nc, microbatch=2, overlap=False)),
        # ISSUE-9 row: compressed activations on the manual lazy-gather path —
        # the compress policy must compose with _save_acts_not_lazy_gathers
        # (gathered weights rematerialized, never quantized)
        ("manual_zero3_compress8",
         mk(zero_stage=3, act_policies=("compress8",) * nb)),
    ]


def decode_memory_fidelity(arch: str = "llama3-405b") -> list[dict]:
    """Serve-side rows (ISSUE-5): predicted vs XLA memory for the decode
    step, resident and host-paged. The paged prediction adds the host-
    resident cold pages to the device peak because the CPU backend folds
    host-kind arguments into ordinary argument buffers — on a backend with a
    real host memory space the comparison splits into the device and host
    columns of memory_analysis."""
    from repro.core.serve_plan import (
        default_paging_spec,
        paging_from_plan,
        serve_memory_estimate,
    )
    from repro.train.step_builder import build_decode_step

    cfg = dataclasses.replace(
        reduced(ARCHS[arch], num_layers=4, d_model=256, vocab_size=2048),
        dtype="float32",
    )
    shape = ShapeConfig("fid-decode", 512, 8, "decode")
    mesh = make_local_mesh()
    mspec = _local_mesh_spec(mesh)
    nc, nb = 5, 4  # embed + 4 layer chunks (values only label the plan)
    full = default_paging_spec(cfg, shape)
    plans = [("decode_resident", MemoryPlan(nc, nb, n_persist=nc))]
    if full.n_pages > 1:
        plans.append(("decode_paged",
                      MemoryPlan(nc, nb, n_persist=nc, n_host=full.n_pages - 1)))
    rows = []
    for name, plan in plans:
        est = serve_memory_estimate(cfg, shape, mspec, plan)
        spec = paging_from_plan(cfg, shape, plan)
        art = build_decode_step(cfg, plan, mesh, shape, paging=spec)
        comp = art.lower(donate=False).compile()
        m = comp.memory_analysis()
        measured = (m.temp_size_in_bytes + m.argument_size_in_bytes
                    + m.host_argument_size_in_bytes + m.host_temp_size_in_bytes)
        predicted = (est["peak_gb"] + est["host_cache_gb"]) * 1e9
        # per-device measurement vs per-device estimate: both sides already
        # shard over the forced local mesh (mspec == the compile mesh)
        rows.append({
            "plan": name,
            "predicted_gb": round(predicted / 1e9, 4),
            "xla_gb": round(measured / 1e9, 4),
            "ratio": round(predicted / max(measured, 1), 3),
        })
    if full.n_pages > 1:
        rows.append(decode_paged_kernel_fidelity(cfg, shape))
    return rows


def decode_paged_kernel_fidelity(cfg, shape: ShapeConfig) -> dict:
    """ISSUE-8 row: the *kernel-path* paged decode step (plain jit over
    KV.decode_step with a kernel-on PagedKV hook — the step-builder path
    host-shards the fetch and stays lax, so the Pallas route is only
    compilable standalone). Predicted: weights + the paged cache partitions
    (hot rings, cold store, one layer's gather working set — the interpret-
    mode pallas_call still materializes its operands as temps on CPU)."""
    from repro.core.chunks import chunk_inventory
    from repro.models import kvcache as KV
    from repro.models import model as M
    from repro.serve.paging import (
        PagedKV,
        cache_partition_bytes,
        choose_paging,
        init_paged_cache,
    )

    B, S = shape.global_batch, shape.seq_len
    spec = choose_paging(KV.cache_len(cfg, S), 8, 2)
    io = PagedKV(spec, use_kernel=True)
    assert io.use_kernel, "kernel row requires the Pallas dispatch"
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = init_paged_cache(cfg, B, S, spec)
    toks = np.zeros((B, 1), np.int32)
    pos = np.zeros((B,), np.int32)  # traced — static ints collapse residency
    fn = jax.jit(lambda p, c, t, ps: KV.decode_step(p, c, t, ps, cfg, kv_io=io))
    m = fn.lower(params, cache, toks, pos).compile().memory_analysis()
    measured = (m.temp_size_in_bytes + m.argument_size_in_bytes
                + m.host_argument_size_in_bytes + m.host_temp_size_in_bytes)
    parts = cache_partition_bytes(cfg, B, S, spec)
    weights = sum(c.param_bytes for c in chunk_inventory(cfg))
    predicted = weights + parts["hbm"] + parts["host"] + parts["transient"]
    return {
        "plan": "decode_paged_kernel",
        "predicted_gb": round(predicted / 1e9, 4),
        "xla_gb": round(measured / 1e9, 4),
        "ratio": round(predicted / max(measured, 1), 3),
    }


def memory_fidelity(arch: str = "llama3-405b") -> list[dict]:
    cfg = dataclasses.replace(
        reduced(ARCHS[arch], num_layers=4, d_model=512, d_ff=2048, vocab_size=4096,
                num_heads=8, num_kv_heads=8, head_dim=64),
        dtype="float32",
    )
    shape = ShapeConfig("fid", 512, 8, "train")
    mesh = make_local_mesh()
    w = build_workload(cfg, shape, _local_mesh_spec(mesh), CPU_HW)

    def row(name, plan, w, mesh):
        est = estimate_memory(w, plan)
        art = build_train_step(cfg, plan, mesh, shape)
        comp = art.lower().compile()
        m = comp.memory_analysis()
        measured = m.temp_size_in_bytes + m.argument_size_in_bytes
        # model predicts states+acts+workspace; args hold states: compare totals
        predicted = est.peak
        return {
            "plan": name,
            "predicted_gb": round(predicted / 1e9, 4),
            "xla_gb": round(measured / 1e9, 4),
            "ratio": round(predicted / max(measured, 1), 3),
        }

    rows = [row(name, plan, w, mesh)
            for name, plan in plans_under_test(w.n_chunks, w.n_blocks)]

    # manual ZeRO requires tp == 1; the local mesh puts the forced devices on
    # the model axis, so these rows get their own pure-DP mesh (and a matching
    # analytic MeshSpec — prediction and compilation must agree on z)
    dp_mesh = jax.make_mesh(
        (len(jax.devices()), 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    w_dp = build_workload(cfg, shape, _local_mesh_spec(dp_mesh), CPU_HW)
    rows += [row(name, plan, w_dp, dp_mesh)
             for name, plan in manual_plans_under_test(w_dp.n_chunks, w_dp.n_blocks)]

    # ISSUE-8 row: same zero3 program with the fused int8 quantize+pack
    # kernel pinned on — the Pallas dispatch must not change what is
    # resident (it replaces three elementwise ops, not any buffer), so this
    # row shares manual_zero3's estimate and gate
    from repro.dist.collectives import set_fused_quant

    try:
        set_fused_quant(True)
        rows.append(row(
            "manual_zero3_fusedq",
            MemoryPlan(w_dp.n_chunks, w_dp.n_blocks, grad_compress="int8_ef",
                       sync_mode="manual", zero_stage=3), w_dp, dp_mesh))
    finally:
        set_fused_quant(None)
    return rows


def runtime_fidelity(arch: str = "llama3-405b", steps: int = 3) -> list[dict]:
    cfg = dataclasses.replace(
        reduced(ARCHS[arch], num_layers=4, d_model=512, d_ff=2048, vocab_size=4096,
                num_heads=8, num_kv_heads=8, head_dim=64),
    )
    shape = ShapeConfig("fid", 512, 8, "train")
    mesh = make_local_mesh()
    w = build_workload(cfg, shape, MESH1, CPU_HW)
    from repro.core import estimate_runtime
    from repro.data.pipeline import SyntheticTokenPipeline

    pipe = SyntheticTokenPipeline(cfg, shape, seed=0)
    batch = pipe.next_sync()
    rows = []
    for name, plan in plans_under_test(w.n_chunks, w.n_blocks):
        modeled = estimate_runtime(w, plan).t_iteration
        art = build_train_step(cfg, plan, mesh, shape)
        state = art.init(jax.random.PRNGKey(0))
        jfn = jax.jit(art.fn)
        jfn(state, batch)[1]["loss"].block_until_ready()  # warmup/compile
        t0 = time.time()
        for _ in range(steps):
            _, metrics = jfn(state, batch)
        metrics["loss"].block_until_ready()
        measured = (time.time() - t0) / steps
        rows.append({"plan": name, "modeled_s": round(modeled, 4),
                     "measured_s": round(measured, 4)})
    # ranking correlation
    mod = [r["modeled_s"] for r in rows]
    mea = [r["measured_s"] for r in rows]
    rho = float(np.corrcoef(np.argsort(np.argsort(mod)), np.argsort(np.argsort(mea)))[0, 1])
    rows.append({"plan": "spearman_rank_corr", "modeled_s": round(rho, 3), "measured_s": ""})
    return rows


def main() -> int:
    """Emit the measured-vs-modeled drift report (CI uploads it per run)."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "reports")))
    ap.add_argument("--skip-runtime", action="store_true",
                    help="memory fidelity only (runtime rows execute real steps)")
    ap.add_argument("--fail-threshold", type=float, default=None,
                    help="fail (exit 1) when any memory prediction/measured "
                         "ratio drifts outside [1/T, T] — the CI smoke gate "
                         "that turns silent estimator rot into a red build")
    args = ap.parse_args()

    # decode rows ride in the "memory" section so the --fail-threshold gate
    # covers the serve estimators too (they are compile-only, like the rest)
    report = {"memory": memory_fidelity() + decode_memory_fidelity()}
    if not args.skip_runtime:
        report["runtime"] = runtime_fidelity()
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, "estimator_fidelity.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    for section, rows in report.items():
        print(f"[fidelity] {section}:")
        for r in rows:
            print(f"  {r}")
    print(f"[fidelity] wrote {out_path}")
    if args.fail_threshold is not None:
        t = args.fail_threshold
        bad = [r for r in report["memory"]
               if not (1.0 / t <= r["ratio"] <= t)]
        if bad:
            print(f"[fidelity] FAIL: {len(bad)} memory ratio(s) outside "
                  f"[{1/t:.2f}, {t:.2f}]: "
                  + ", ".join(f"{r['plan']}={r['ratio']}" for r in bad))
            return 1
        print(f"[fidelity] OK: all memory ratios within [{1/t:.2f}, {t:.2f}]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
