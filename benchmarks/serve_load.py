"""Request-level serving load harness: seeded arrivals, latency percentiles.

Drives the decode engine with a seeded Poisson request stream (mixed
prompt/output lengths: mostly short prompts plus a long tail) under each
admission mode and emits ``BENCH_serve.json``:

  * ``replay``  — legacy teacher-forced prefill, one prompt token per tick;
  * ``whole``   — chunked-prefill program run to completion per prompt (the
    stall-heavy baseline: in-flight streams wait out every chunk);
  * ``chunked`` — cost-model-sized chunks interleaved with decode ticks
    (at most ``chunk_budget`` consecutive prefill calls per stall).

Per mode: p50/p99 request latency, p50/p99 TTFT, p99 inter-token latency,
aggregate tokens/sec, tick counts, and a sha256 checksum of the finished
token streams. Greedy decode is deterministic, so the checksum and tick
counts are reproducible for a fixed seed (and equal ACROSS modes — the
prefill dataflow is bitwise-identical to replay); the wall-clock fields are
the measurement and naturally jitter.

    PYTHONPATH=src python benchmarks/serve_load.py --smoke --out BENCH_serve.json

``--smoke`` additionally gates (exit 1 on failure): all modes drain, token
checksums agree across modes, chunked admission beats whole-prompt admission
on p99 inter-token latency, and a second chunked run reproduces the first
(checksum + tick counts).
"""
import argparse
import hashlib
import json
import random
import sys

from repro.compat import ensure_jax_compat

ensure_jax_compat()

import jax  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core.plan import MemoryPlan  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.models import kvcache as KV  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve import DecodeEngine, Request, choose_paging  # noqa: E402

MODES = ("replay", "whole", "chunked")


def build_workload(seed: int, n_requests: int, vocab: int, *,
                   mean_gap_ticks: float = 3.0, long_frac: float = 0.3,
                   short_prompt=(3, 8), long_prompt=(24, 44),
                   max_new=(4, 12)) -> list[tuple[int, Request]]:
    """Seeded (arrival_tick, Request) stream: Poisson arrivals (exponential
    inter-arrival gaps, floored to engine ticks), 70/30 short/long prompts,
    uniform output lengths. Same seed -> same stream, so every mode (and
    every rerun) serves identical work."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += rng.expovariate(1.0 / mean_gap_ticks)
        lo, hi = long_prompt if rng.random() < long_frac else short_prompt
        prompt = [rng.randrange(1, vocab) for _ in range(rng.randint(lo, hi))]
        out.append((int(t), Request(rid, prompt, rng.randint(*max_new))))
    return out


def drive(engine: DecodeEngine, arrivals: list[tuple[int, Request]],
          max_steps: int = 5000):
    """Tick the engine against the arrival schedule: submit every request
    whose arrival tick has passed, fast-forward over idle gaps (no busy
    ticks between bursts), and drain. Returns the engine report."""
    pending = sorted(arrivals, key=lambda a: a[0])
    tick = steps = 0
    while (pending or not engine.scheduler.idle) and steps < max_steps:
        while pending and pending[0][0] <= tick:
            engine.submit([pending.pop(0)[1]])
        if engine.scheduler.idle:
            tick = pending[0][0]
            continue
        engine.step_once()
        tick += 1
        steps += 1
    return engine.report()


def token_checksum(report) -> str:
    """sha256 over the finished/rejected token streams (sorted by rid) —
    the deterministic identity of a run."""
    payload = json.dumps({
        "finished": sorted((rid, toks) for rid, toks in report.finished.items()),
        "rejected": sorted((rid, toks) for rid, toks in report.rejected.items()),
        "truncated": sorted(report.truncated),
    }, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


def run_mode(mode: str, cfg, plan, mesh, shape, params, paging, arrivals,
             chunk: int | None, max_steps: int,
             telemetry=None) -> dict:
    engine = DecodeEngine(cfg, plan, mesh, shape, params, paging=paging,
                          admission=mode,
                          prefill_chunk=None if mode == "replay" else chunk,
                          telemetry=telemetry)
    engine.warmup()  # compile outside the measured window
    report = drive(engine, arrivals, max_steps=max_steps)
    # the engine's registry is the one clock: every timing/count below is
    # EngineReport's own registry-backed view (same keys and rounding as
    # always — the harness only adds the checksum, kept in its historical
    # slot right after "drained")
    out = {}
    for key, value in report.to_dict().items():
        out[key] = value
        if key == "drained":
            # deterministic for a fixed seed (greedy decode, seeded stream)
            out["token_checksum"] = token_checksum(report)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-405b")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--hot-pages", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk size for whole/chunked modes "
                         "(0 = cost-model choice)")
    ap.add_argument("--max-steps", type=int, default=5000)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="gate: drained, cross-mode checksum equality, "
                         "chunked p99 ITL < whole p99 ITL, and a second "
                         "chunked run reproducing the first")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    mesh = make_local_mesh()
    shape = ShapeConfig("serve_load", args.seq_len, args.batch_slots, "decode")
    s_kv = KV.cache_len(cfg, args.seq_len)
    paging = choose_paging(s_kv, args.page_size, args.hot_pages)
    nc, nb = 3, 2
    plan = MemoryPlan(nc, nb, n_persist=nc, n_host=paging.n_cold)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    chunk = args.chunk if args.chunk > 0 else None

    workload = build_workload(args.seed, args.requests, cfg.vocab_size)
    print(f"[serve_load] {args.arch} seed={args.seed}: "
          f"{len(workload)} requests over {workload[-1][0]} ticks, "
          f"prompts {min(len(r.prompt_tokens) for _, r in workload)}-"
          f"{max(len(r.prompt_tokens) for _, r in workload)} tokens, "
          f"paged cache ({paging.n_cold} cold pages -> host)")

    modes = {}
    for mode in MODES:
        arrivals = build_workload(args.seed, args.requests, cfg.vocab_size)
        modes[mode] = run_mode(mode, cfg, plan, mesh, shape, params, paging,
                               arrivals, chunk, args.max_steps)
        m = modes[mode]
        print(f"[serve_load] {mode:>7}: {m['generated_tokens']} tok "
              f"in {m['steps']} ticks ({m['prefill_ticks']} prefill / "
              f"{m['decode_ticks']} decode), {m['tokens_per_s']:.1f} tok/s, "
              f"p50/p99 latency {m['p50_latency_s']:.4f}/"
              f"{m['p99_latency_s']:.4f}s, p99 TTFT {m['p99_ttft_s']:.4f}s, "
              f"p99 ITL {m['p99_itl_s']:.4f}s")

    comparison = {
        "chunked_lt_whole_p99_itl":
            modes["chunked"]["p99_itl_s"] < modes["whole"]["p99_itl_s"],
        "checksums_agree":
            len({m["token_checksum"] for m in modes.values()}) == 1,
    }
    bench = {
        "bench": "serve_load",
        "seed": args.seed,
        "arch": args.arch,
        "workload": {
            "requests": args.requests,
            "seq_len": args.seq_len,
            "batch_slots": args.batch_slots,
            "page_size": args.page_size,
            "hot_pages": args.hot_pages,
            "chunk": chunk,
            "arrival_ticks": [t for t, _ in workload],
            "prompt_lens": [len(r.prompt_tokens) for _, r in workload],
            "max_new": [r.max_new_tokens for _, r in workload],
        },
        "modes": modes,
        "comparison": comparison,
    }
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    print(f"[serve_load] wrote {args.out}")

    if args.smoke:
        failures = []
        for mode, m in modes.items():
            if not m["drained"]:
                failures.append(f"{mode} did not drain in {m['steps']} ticks")
        if not comparison["checksums_agree"]:
            failures.append("token checksums differ across admission modes")
        if not comparison["chunked_lt_whole_p99_itl"]:
            failures.append(
                f"chunked p99 ITL {modes['chunked']['p99_itl_s']}s not below "
                f"whole-prompt {modes['whole']['p99_itl_s']}s")
        rerun = run_mode("chunked", cfg, plan, mesh, shape, params, paging,
                         build_workload(args.seed, args.requests, cfg.vocab_size),
                         chunk, args.max_steps)
        for key in ("token_checksum", "steps", "prefill_ticks",
                    "decode_ticks", "generated_tokens"):
            if rerun[key] != modes["chunked"][key]:
                failures.append(f"chunked rerun not deterministic: {key} "
                                f"{rerun[key]} != {modes['chunked'][key]}")
        if failures:
            for f_ in failures:
                print(f"[serve_load] FAIL: {f_}", file=sys.stderr)
            return 1
        print("[serve_load] smoke OK: drained, checksums agree, chunked "
              "p99 ITL below whole-prompt, rerun deterministic")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
